//! L3 hot-path micro-benchmarks (criterion-free harness):
//!   * hiding selector: quickselect vs full sort (the §Perf optimization)
//!   * weighted samplers: alias build+draw vs Fenwick draw/update
//!   * batch assembly gather
//!   * worker pool: W ∈ {1, 2, 4} lanes, both schedules (mock backend)
//!   * executor step latency (train vs fwd) — the PJRT dispatch floor
//!
//! Prints ns/op style rows and records them in results/hotpath.json.

use kakurenbo::data::batch::BatchAssembler;
use kakurenbo::data::shard::shard_order_aligned;
use kakurenbo::data::synth::{gauss_mixture, GaussMixtureCfg};
use kakurenbo::engine::testbed::MockBackend;
use kakurenbo::engine::{Engine, EvalSink, StepMode, WorkerPool};
use kakurenbo::hiding::selector::{select, SelectMode, SelectorCfg};
use kakurenbo::report::BenchCtx;
use kakurenbo::runtime::ModelExecutor;
use kakurenbo::sampler::alias::AliasTable;
use kakurenbo::sampler::fenwick::FenwickSampler;
use kakurenbo::state::SampleState;
use kakurenbo::util::rng::Rng;
use kakurenbo::util::table::Table;
use kakurenbo::util::timer::Timer;

fn time_it(reps: usize, mut f: impl FnMut()) -> f64 {
    let t = Timer::start();
    for _ in 0..reps {
        f();
    }
    t.elapsed_s() / reps as f64
}

fn main() -> anyhow::Result<()> {
    let ctx = BenchCtx::init("hot-path micro-benchmarks")?;
    let n = ctx.scale(1_000_000, 100_000);
    let reps = ctx.scale(20, 5);
    let mut rng = Rng::new(1);
    let mut payload = Vec::new();
    let mut t = Table::new(format!("hot paths (N={n})")).header(&["op", "time", "per-elem"]);
    let mut row = |name: &str, secs: f64, n_elems: usize, payload: &mut Vec<kakurenbo::util::json::Json>| {
        t.row(vec![
            name.to_string(),
            format!("{:.3} ms", secs * 1e3),
            format!("{:.1} ns", secs / n_elems as f64 * 1e9),
        ]);
        payload.push(kakurenbo::jobj![("op", name), ("seconds", secs), ("n", n_elems)]);
    };

    // --- selector ------------------------------------------------------------
    let mut state = SampleState::new(n);
    for i in 0..n {
        state.record(i, rng.f32() * 10.0, rng.chance(0.6), rng.f32(), 0);
    }
    let cfg_q = SelectorCfg { mode: SelectMode::QuickSelect, ..Default::default() };
    let cfg_s = SelectorCfg { mode: SelectMode::FullSort, ..Default::default() };
    let tq = time_it(reps, || {
        let s = select(&state, 0.3, &cfg_q);
        std::hint::black_box(s.hidden.len());
    });
    let ts = time_it(reps, || {
        let s = select(&state, 0.3, &cfg_s);
        std::hint::black_box(s.hidden.len());
    });
    row("selector quickselect (O(N))", tq, n, &mut payload);
    row("selector full-sort (O(N log N))", ts, n, &mut payload);
    println!("  selector speedup quickselect vs sort: {:.2}x", ts / tq);

    // --- samplers --------------------------------------------------------------
    let weights: Vec<f64> = (0..n).map(|i| (i % 100) as f64 + 0.5).collect();
    let tb = time_it(reps.max(3), || {
        let a = AliasTable::new(&weights);
        std::hint::black_box(a.len());
    });
    row("alias build", tb, n, &mut payload);
    let table = AliasTable::new(&weights);
    let td = time_it(3, || {
        let mut acc = 0u64;
        for _ in 0..n {
            acc += table.draw(&mut rng) as u64;
        }
        std::hint::black_box(acc);
    });
    row("alias draw xN", td, n, &mut payload);
    let fenwick = FenwickSampler::new(&weights);
    let tf = time_it(3, || {
        let mut acc = 0u64;
        for _ in 0..n {
            acc += fenwick.draw(&mut rng).unwrap() as u64;
        }
        std::hint::black_box(acc);
    });
    row("fenwick draw xN", tf, n, &mut payload);

    // --- batch assembly ---------------------------------------------------------
    let data = gauss_mixture(
        &GaussMixtureCfg { n_train: 8192, n_val: 8, dim: 192, classes: 32, ..Default::default() },
        3,
    )
    .train;
    let mut asm = BatchAssembler::new(&data, 64);
    let idx: Vec<u32> = (0..64u32).map(|i| (i * 113) % 8192).collect();
    let ta = time_it(5000, || {
        asm.fill(&data, &idx, None);
        std::hint::black_box(asm.real);
    });
    row("batch assembly (64x192 gather)", ta, 64, &mut payload);

    // --- worker pool (mock backend, full 8192-sample sweep) -------------------
    // W gather lanes behind the deterministic reduction: the serial-
    // equivalent schedule parallelizes only the host gather; the data-
    // parallel schedule additionally fans the (mock) device work out
    // across replicas — the W=4 vs W=1 wall-clock ratio tracks the pool's
    // scaling in the perf trajectory.
    let preps = ctx.scale(10, 3);
    let order: Vec<u32> = (0..8192u32).collect();
    let mut w1_dp = 0.0;
    for wk in [1usize, 2, 4] {
        let shards = shard_order_aligned(&order, wk, 64);
        let mut pool = WorkerPool::new(&data, 64);
        let t_se = time_it(preps, || {
            let mut be = MockBackend::new();
            let mut sink = EvalSink::default();
            pool.run_serial_equivalent(&mut be, &data, &shards, StepMode::Forward, &mut sink)
                .unwrap();
            std::hint::black_box(sink.result());
        });
        let t_dp = time_it(preps, || {
            let mut be = MockBackend::new();
            let mut sink = EvalSink::default();
            pool.run_data_parallel(&mut be, &data, &shards, StepMode::Forward, &mut sink)
                .unwrap();
            std::hint::black_box(sink.result());
        });
        row(&format!("pool serial-equiv fwd sweep W={wk}"), t_se, 8192, &mut payload);
        row(&format!("pool data-parallel fwd sweep W={wk}"), t_dp, 8192, &mut payload);
        if wk == 1 {
            w1_dp = t_dp;
        } else {
            println!("  pool data-parallel W={wk}: {:.2}x vs W=1", w1_dp / t_dp);
        }
    }

    // --- executor step latency ---------------------------------------------------
    let mut exec = ModelExecutor::new(&ctx.rt, "cnn_c32_b64", 1)?;
    let b = exec.meta.batch;
    let x = vec![0.1f32; b * exec.meta.sample_dim()];
    let y = vec![0i32; b];
    let sw = vec![1.0f32; b];
    exec.train_step(&x, &y, &sw, 0.01)?; // warmup
    let tt = time_it(ctx.scale(50, 10), || {
        exec.train_step(&x, &y, &sw, 0.01).unwrap();
    });
    let tf2 = time_it(ctx.scale(50, 10), || {
        exec.fwd_stats(&x, &y).unwrap();
    });
    row("executor train_step (B=64 cnn)", tt, b, &mut payload);
    row("executor fwd_stats (B=64 cnn)", tf2, b, &mut payload);
    println!("  bwd+update share of step: {:.0}%", (1.0 - tf2 / tt) * 100.0);

    // --- step engine: serial vs pipelined (gather overlapped with exec) ------
    let cfg = kakurenbo::config::presets::by_name("cifar100_wrn")?;
    let tv = cfg.dataset.generate(cfg.seed);
    let mut eexec = ModelExecutor::new(&ctx.rt, &cfg.variant, cfg.seed)?;
    let mut eng = Engine::new(&tv.train, eexec.meta.batch);
    let sweep: Vec<u32> = (0..tv.train.n as u32).collect();
    let ereps = ctx.scale(5, 2);
    let mut sweep_time = |eng: &mut Engine, exec: &mut ModelExecutor| {
        time_it(ereps, || {
            let mut sink = EvalSink::default();
            eng.run(exec, &tv.train, &sweep, None, StepMode::Forward, &mut sink)
                .unwrap();
            std::hint::black_box(sink.result());
        })
    };
    eng.overlap = false;
    let e_serial = sweep_time(&mut eng, &mut eexec);
    eng.overlap = true;
    let e_olap = sweep_time(&mut eng, &mut eexec);
    row("engine fwd sweep serial", e_serial, tv.train.n, &mut payload);
    row("engine fwd sweep pipelined", e_olap, tv.train.n, &mut payload);
    println!(
        "  engine pipelining: {:.2}x vs serial (1 prefetch thread, {} cores)",
        e_serial / e_olap,
        kakurenbo::util::threadpool::default_threads()
    );

    t.print();
    ctx.save_json("hotpath", &kakurenbo::util::json::Json::Arr(payload))?;
    Ok(())
}
