//! Figure 5: histogram of the lagging loss as training progresses
//! (ImageNet proxy).
//!
//! Paper shape: early epochs ~Gaussian; later epochs pile most samples
//! into the lowest bins (">50% of samples below 5% of the max loss from
//! epoch 30") while a hard tail persists — the motivation for RF.

use kakurenbo::config::{presets, StrategyConfig};
use kakurenbo::coordinator::run_experiment;
use kakurenbo::report::BenchCtx;

fn main() -> anyhow::Result<()> {
    let ctx = BenchCtx::init("Fig 5: lagging-loss histograms across epochs")?;
    let mut cfg = presets::by_name("imagenet_resnet50")?;
    ctx.scale_config(&mut cfg);
    cfg.strategy = StrategyConfig::Baseline; // paper plots the plain run
    cfg.detailed_metrics = true;
    cfg.name = "fig5".into();
    let r = run_experiment(&ctx.rt, cfg)?;

    let picks: Vec<usize> = {
        let e = r.records.len();
        vec![0, e / 4, e / 2, 3 * e / 4, e - 1]
    };
    let mut payload = Vec::new();
    let mut low_fracs = Vec::new();
    for &e in &picks {
        if let Some(h) = &r.records[e].loss_hist {
            println!("  epoch {e:>3}: {}  (max-loss bin edge {:.2})", h.sparkline(), h.hi);
            // fraction of samples with loss < 5% of the max observed loss
            let bins_5pct = (h.counts.len() as f64 * 0.05).ceil() as usize;
            let low: u64 = h.counts[..bins_5pct.max(1)].iter().sum();
            let frac = low as f64 / h.total() as f64;
            low_fracs.push((e, frac));
            payload.push(kakurenbo::jobj![
                ("epoch", e),
                ("lo", h.lo),
                ("hi", h.hi),
                ("counts", h.counts.iter().map(|&c| c as usize).collect::<Vec<_>>()),
                ("frac_below_5pct_maxloss", frac),
            ]);
        }
    }
    println!("\nfraction of samples below 5% of max loss:");
    for (e, f) in &low_fracs {
        println!("  epoch {e:>3}: {:.1}%", f * 100.0);
    }
    // paper check: low-loss mass grows over training
    assert!(
        low_fracs.last().unwrap().1 > low_fracs.first().unwrap().1,
        "low-loss mass should grow as training progresses"
    );
    ctx.save_json("fig5_loss_hist", &kakurenbo::util::json::Json::Arr(payload))?;
    Ok(())
}
