//! Figure 3: test accuracy vs epoch for different maximum hiding
//! fractions F (paper: F∈{0.1..0.5}; small F matches baseline, large F
//! visibly degrades).

use kakurenbo::config::{presets, StrategyConfig};
use kakurenbo::coordinator::run_experiment;
use kakurenbo::report::{convergence_json, BenchCtx};

fn main() -> anyhow::Result<()> {
    let ctx = BenchCtx::init("Fig 3: accuracy vs epoch across hiding fractions")?;
    let mut base = presets::by_name("imagenet_resnet50")?;
    ctx.scale_config(&mut base);

    let mut runs = Vec::new();
    // F = 0 is the baseline curve.
    let mut cfg = base.clone();
    cfg.strategy = StrategyConfig::Baseline;
    cfg.name = "fig3/baseline".into();
    let mut r = run_experiment(&ctx.rt, cfg)?;
    r.strategy = "F=0.0 (baseline)".into();
    println!("  F=0.0 acc {:.4}", r.best_acc);
    runs.push(r);

    for f in [0.1, 0.2, 0.3, 0.4, 0.5] {
        let mut cfg = base.clone();
        cfg.strategy = StrategyConfig::kakurenbo(f);
        cfg.name = format!("fig3/f{f}");
        let mut r = run_experiment(&ctx.rt, cfg)?;
        r.strategy = format!("F={f}");
        println!("  F={f} acc {:.4} time {:.1}s", r.best_acc, r.total_time);
        runs.push(r);
    }

    // print final accuracies as the figure's summary
    println!("\nfinal accuracy by fraction:");
    for r in &runs {
        println!("  {:<16} {:.4}", r.strategy, r.best_acc);
    }
    ctx.save_json("fig3_fractions", &convergence_json(&runs))?;
    Ok(())
}
