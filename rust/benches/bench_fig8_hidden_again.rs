//! Figure 8: per-epoch counts of max-hidden candidates, actually hidden
//! samples, and "hidden again" (hidden in consecutive epochs).
//!
//! Paper shape: only ~30% of hidden samples repeat between epochs (the
//! importance ranking is genuinely dynamic), and the moved-back count
//! shrinks over training as prediction confidence rises.

use kakurenbo::config::{presets, StrategyConfig};
use kakurenbo::coordinator::run_experiment;
use kakurenbo::report::BenchCtx;
use kakurenbo::util::table::Table;

fn main() -> anyhow::Result<()> {
    let ctx = BenchCtx::init("Fig 8: hidden / hidden-again / moved-back per epoch")?;
    let mut cfg = presets::by_name("imagenet_resnet50")?;
    ctx.scale_config(&mut cfg);
    cfg.strategy = StrategyConfig::kakurenbo(0.3);
    cfg.name = "fig8".into();
    let r = run_experiment(&ctx.rt, cfg)?;

    let mut t = Table::new("Fig 8 — hidden-set dynamics").header(&[
        "Epoch", "Max hidden", "Hidden", "Hidden again", "again/hidden", "Moved back",
    ]);
    let mut series = Vec::new();
    for rec in &r.records {
        let ratio = if rec.hidden > 0 {
            rec.hidden_again as f64 / rec.hidden as f64
        } else {
            0.0
        };
        t.row(vec![
            rec.epoch.to_string(),
            rec.max_hidden.to_string(),
            rec.hidden.to_string(),
            rec.hidden_again.to_string(),
            format!("{:.2}", ratio),
            rec.moved_back.to_string(),
        ]);
        series.push(kakurenbo::jobj![
            ("epoch", rec.epoch),
            ("max_hidden", rec.max_hidden),
            ("hidden", rec.hidden),
            ("hidden_again", rec.hidden_again),
            ("moved_back", rec.moved_back),
        ]);
    }
    t.print();

    // paper checks
    let mid: Vec<&kakurenbo::metrics::EpochRecord> =
        r.records.iter().filter(|x| x.hidden > 0).collect();
    if mid.len() >= 4 {
        let early_mb = mid[0].moved_back;
        let late_mb = mid[mid.len() - 1].moved_back;
        println!("moved-back early {early_mb} -> late {late_mb} (should shrink)");
        let mean_again: f64 = mid
            .iter()
            .map(|x| x.hidden_again as f64 / x.hidden.max(1) as f64)
            .sum::<f64>()
            / mid.len() as f64;
        println!("mean hidden-again ratio {mean_again:.2} (paper: ~0.3 — dynamic selection)");
    }
    ctx.save_json("fig8_hidden_again", &kakurenbo::util::json::Json::Arr(series))?;
    Ok(())
}
