//! Extension bench (paper Appendix C.4 discussion): KAKURENBO vs the
//! related dynamic-pruning methods the paper discusses but does not run —
//! InfoBatch [28] (unbiased dynamic pruning), EL2N [15] (early
//! error-norm pruning), and PFB (arXiv 2506.23674, cached-feature
//! pre-forward pruning) — plus Random hiding as the floor.
//!
//! Expectation from the paper's arguments: InfoBatch is competitive on
//! accuracy (its rescaling keeps the gradient unbiased) with similar
//! step savings; EL2N loses accuracy when the score epoch is early and
//! the pruning permanent; PFB trades a periodic embedding harvest for
//! zero per-epoch scoring forwards; Random sits below all informed
//! methods.

use kakurenbo::config::{presets, StrategyConfig};
use kakurenbo::report::{comparison_table, BenchCtx};

fn main() -> anyhow::Result<()> {
    let ctx = BenchCtx::init("Extensions: InfoBatch / EL2N / PFB / Random vs KAKURENBO")?;
    let mut cfg = presets::by_name("imagenet_resnet50")?;
    ctx.scale_config(&mut cfg);
    let score_epoch = (cfg.epochs / 5).max(2);

    let strategies = vec![
        ("Baseline".to_string(), StrategyConfig::Baseline),
        ("KAKURENBO".to_string(), StrategyConfig::kakurenbo(0.3)),
        ("InfoBatch".to_string(), StrategyConfig::InfoBatch { r: 0.5 }),
        (
            "EL2N".to_string(),
            StrategyConfig::El2n { score_epoch, fraction: 0.3, restart: false },
        ),
        (
            "PFB".to_string(),
            StrategyConfig::Pfb { fraction: 0.3, refresh_every: 3 },
        ),
        ("Random".to_string(), StrategyConfig::RandomHiding { fraction: 0.3 }),
    ];
    let runs = comparison_table(
        &ctx,
        "Extensions — dynamic pruning methods (ImageNet proxy)",
        &cfg,
        &strategies,
    )?;
    ctx.save_runs("ext_strategies", &runs)?;
    Ok(())
}
