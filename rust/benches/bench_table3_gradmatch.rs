//! Table 3: KAKURENBO vs Grad-Match on a single worker (paper setting:
//! CIFAR-100 / ResNet-18, cutting fraction 0.3).
//!
//! Paper shape: GradMatch loses ~1.1% accuracy and only gains ~5% time;
//! KAKURENBO at a single worker *loses* time (+2.7%) because the selection
//! overhead is not amortized — KAKURENBO is optimized for multi-worker
//! runs (§4.2).

use kakurenbo::config::{presets, StrategyConfig};
use kakurenbo::report::{comparison_table, BenchCtx};

fn main() -> anyhow::Result<()> {
    let ctx = BenchCtx::init("Table 3: Grad-Match comparison (single worker)")?;
    let mut cfg = presets::by_name("gradmatch_cifar")?;
    ctx.scale_config(&mut cfg);
    cfg.workers = 1;

    let strategies = vec![
        ("Baseline".to_string(), StrategyConfig::Baseline),
        (
            "Grad-Match-0.3".to_string(),
            StrategyConfig::GradMatch { fraction: 0.3, every_r: 3 },
        ),
        ("KAKURENBO-0.3".to_string(), StrategyConfig::kakurenbo(0.3)),
    ];
    let runs = comparison_table(&ctx, "Table 3 — CIFAR-100 proxy, 1 worker", &cfg, &strategies)?;
    ctx.save_runs("table3_gradmatch", &runs)?;
    Ok(())
}
