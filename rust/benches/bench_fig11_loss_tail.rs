//! Figure 11: loss distributions of the full DeepCAM training set vs the
//! bottom-98% and top-2% (by loss) over the last epochs.
//!
//! Paper shape: the top-2% tail keeps a substantially higher loss through
//! the final epochs — hard-to-learn or mislabeled samples — motivating
//! DropTop.  Our proxy plants that tail via `corrupt_frac` mask
//! corruption; the bench additionally verifies the planted corrupt
//! samples are over-represented in the top-2%.

use kakurenbo::config::{presets, StrategyConfig};
use kakurenbo::coordinator::Trainer;
use kakurenbo::report::BenchCtx;
use kakurenbo::util::stats::{mean, percentile};
use kakurenbo::util::table::Table;

fn main() -> anyhow::Result<()> {
    let ctx = BenchCtx::init("Fig 11: DeepCAM loss tail (top-2% vs bottom-98%)")?;
    let mut cfg = presets::by_name("deepcam")?;
    ctx.scale_config(&mut cfg);
    if let kakurenbo::config::DatasetConfig::DeepcamProxy(ref mut c) = cfg.dataset {
        c.corrupt_frac = 0.02;
    }
    cfg.strategy = StrategyConfig::Baseline;
    cfg.name = "fig11".into();

    let mut trainer = Trainer::new(&ctx.rt, cfg.clone())?;
    let last_k = 5.min(cfg.epochs);
    let mut t = Table::new("Fig 11 — per-epoch loss split").header(&[
        "Epoch", "mean(all)", "mean(bot98%)", "mean(top2%)", "top2%/bot98%",
    ]);
    let mut payload = Vec::new();
    for epoch in 0..cfg.epochs {
        trainer.run_epoch(epoch)?;
        if epoch + last_k < cfg.epochs {
            continue;
        }
        let losses: Vec<f32> = trainer
            .state
            .loss
            .iter()
            .copied()
            .filter(|l| l.is_finite())
            .collect();
        let p98 = percentile(&losses, 98.0);
        let bot: Vec<f32> = losses.iter().copied().filter(|&l| l <= p98).collect();
        let top: Vec<f32> = losses.iter().copied().filter(|&l| l > p98).collect();
        let (ma, mb, mt) = (mean(&losses), mean(&bot), mean(&top));
        t.row(vec![
            epoch.to_string(),
            format!("{ma:.4}"),
            format!("{mb:.4}"),
            format!("{mt:.4}"),
            format!("{:.1}x", mt / mb.max(1e-9)),
        ]);
        payload.push(kakurenbo::jobj![
            ("epoch", epoch),
            ("mean_all", ma),
            ("mean_bot98", mb),
            ("mean_top2", mt),
        ]);
    }
    t.print();

    // planted-noise check: corrupt samples should dominate the top tail
    let losses = &trainer.state.loss;
    let finite: Vec<f32> = losses.iter().copied().filter(|l| l.is_finite()).collect();
    let p98 = percentile(&finite, 98.0);
    let n = trainer.data.train.n;
    let top_idx: Vec<usize> = (0..n).filter(|&i| losses[i] > p98).collect();
    let corrupt_in_top =
        top_idx.iter().filter(|&&i| trainer.data.train.noisy[i]).count();
    let total_corrupt = trainer.data.train.noisy.iter().filter(|&&b| b).count();
    println!(
        "top-2% contains {corrupt_in_top}/{} samples; dataset has {total_corrupt} corrupted ({}x over-representation)",
        top_idx.len(),
        (corrupt_in_top as f64 / top_idx.len().max(1) as f64)
            / (total_corrupt as f64 / n as f64).max(1e-9)
    );
    ctx.save_json("fig11_loss_tail", &kakurenbo::util::json::Json::Arr(payload))?;
    Ok(())
}
