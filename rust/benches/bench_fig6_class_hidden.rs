//! Figures 6 & 7: number of hidden samples per class across epochs
//! (ImageNet proxy).
//!
//! Paper shape: hiding is class-heterogeneous and drifts over training —
//! easy classes are hidden much more than hard ones, and a class's hidden
//! count changes epoch to epoch (the selection is truly dynamic).

use kakurenbo::config::{presets, StrategyConfig};
use kakurenbo::coordinator::run_experiment;
use kakurenbo::report::BenchCtx;
use kakurenbo::util::table::Table;

fn main() -> anyhow::Result<()> {
    let ctx = BenchCtx::init("Fig 6/7: hidden samples per class per epoch")?;
    let mut cfg = presets::by_name("imagenet_resnet50")?;
    ctx.scale_config(&mut cfg);
    cfg.strategy = StrategyConfig::kakurenbo(0.3);
    cfg.detailed_metrics = true;
    cfg.name = "fig6".into();
    let r = run_experiment(&ctx.rt, cfg)?;

    let e = r.records.len();
    let picks = [e / 4, e / 2, e - 1];
    let classes = r.records[e - 1].hidden_per_class.len();
    let show = classes.min(16);

    let mut t = Table::new("Fig 6 — hidden per class (first classes)").header(
        &std::iter::once("epoch".to_string())
            .chain((0..show).map(|c| format!("c{c}")))
            .collect::<Vec<_>>()
            .iter()
            .map(String::as_str)
            .collect::<Vec<_>>(),
    );
    for &ep in &picks {
        let counts = &r.records[ep].hidden_per_class;
        if counts.is_empty() {
            continue;
        }
        t.row(
            std::iter::once(ep.to_string())
                .chain(counts[..show].iter().map(|c| c.to_string()))
                .collect(),
        );
    }
    t.print();

    // heterogeneity check (Fig. 7): per-class totals over the run differ
    let mut totals = vec![0usize; classes];
    for rec in &r.records {
        for (c, &v) in rec.hidden_per_class.iter().enumerate() {
            totals[c] += v;
        }
    }
    let max = *totals.iter().max().unwrap_or(&0);
    let min = *totals.iter().min().unwrap_or(&0);
    println!("per-class cumulative hidden: min {min}, max {max} (heterogeneous: {})", max > 2 * (min + 1));

    let payload = kakurenbo::util::json::Json::Arr(
        r.records
            .iter()
            .map(|rec| {
                kakurenbo::jobj![
                    ("epoch", rec.epoch),
                    ("hidden_per_class", rec.hidden_per_class.clone()),
                ]
            })
            .collect(),
    );
    ctx.save_json("fig6_class_hidden", &payload)?;
    Ok(())
}
