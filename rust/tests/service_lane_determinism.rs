//! The service-lane determinism contract: evaluation (and checkpointing)
//! moved onto the async background lanes must be **bitwise identical** to
//! the synchronous path — the lanes consume exact exported typed
//! snapshots, so going async can change *when* the numbers are computed
//! but never *what* they are.  The split-lane design (independent eval /
//! checkpoint queues) and the params-only eval tier must preserve this.
//!
//! Two layers of coverage:
//!   * engine-level (mock backend, always runs): eval-lane results vs the
//!     engine's `EvalSink` path on the same state, across both snapshot
//!     tiers;
//!   * trainer-level (PJRT, skipped without artifacts): full runs with
//!     `--service-lane on` vs `off` must produce bitwise-identical
//!     records (loss curves, val accuracy, hidden counts), final
//!     parameters, and byte-identical checkpoints — including composed
//!     with `--dp average`.

use std::sync::Arc;

use kakurenbo::config::{presets, DatasetConfig, StrategyConfig};
use kakurenbo::coordinator::Trainer;
use kakurenbo::data::synth::{gauss_mixture, GaussMixtureCfg};
use kakurenbo::engine::testbed::MockBackend;
use kakurenbo::engine::{
    CheckpointWriter, DataParallel, Engine, EvalSink, ServiceEvent, ServiceLaneKind, ServiceLanes,
    SnapshotTier, StateExchange, StepMode,
};
use kakurenbo::runtime::{default_artifacts_dir, XlaRuntime};
use kakurenbo::util::artifact::WriteStats;

const B: usize = 8;

/// Engine-level: the eval lane's eval of an exported snapshot is bitwise
/// identical to the engine's synchronous eval of the same backend state —
/// on the params-only tier *and* the full tier.
#[test]
fn async_eval_matches_sync_eval_bitwise() {
    let tv = gauss_mixture(
        &GaussMixtureCfg { n_train: 64, n_val: 37, dim: 6, classes: 3, ..Default::default() },
        11,
    );
    // move the backend off its init so the test is not vacuous
    let mut primary = MockBackend::new();
    let mut eng = Engine::new(&tv.train, B);
    let order: Vec<u32> = (0..64).collect();
    let mut sink = EvalSink::default();
    eng.run(&mut primary, &tv.train, &order, None, StepMode::Train { lr: 0.05 }, &mut sink)
        .unwrap();

    // sync: engine + EvalSink over the validation order
    let val_order: Vec<u32> = (0..tv.val.n as u32).collect();
    let mut sync_sink = EvalSink::default();
    let mut eval_eng = Engine::new(&tv.val, B);
    eval_eng
        .run(&mut primary, &tv.val, &val_order, None, StepMode::Forward, &mut sync_sink)
        .unwrap();
    let (sync_acc, sync_loss) = sync_sink.result();

    // async: the eval lane's replica evaluates the exported snapshots
    let mut lanes = ServiceLanes::spawn(
        primary.replica_builder().unwrap(),
        tv.val.clone(),
        B,
        None,
    )
    .unwrap();
    let params_snap = Arc::new(primary.export_snapshot(SnapshotTier::Params).unwrap());
    let full_snap = Arc::new(primary.export_snapshot(SnapshotTier::Full).unwrap());
    lanes.submit_eval(9, params_snap).unwrap();
    lanes.submit_eval(10, full_snap).unwrap();
    let events = lanes.drain().unwrap();
    assert_eq!(events.len(), 2);
    for (ev, want_epoch) in events.iter().zip([9usize, 10]) {
        match ev {
            ServiceEvent::Eval { epoch, acc, loss, .. } => {
                assert_eq!(*epoch, want_epoch);
                assert_eq!(acc.to_bits(), sync_acc.to_bits());
                assert_eq!(loss.to_bits(), sync_loss.to_bits());
            }
            other => panic!("unexpected event {other:?}"),
        }
    }
}

/// Engine-level: a stale snapshot evaluates the *snapshot*, not whatever
/// the primary has trained to since — the lane must be time-shifted, not
/// state-shifted.
#[test]
fn lane_evaluates_the_snapshot_not_the_live_backend() {
    let tv = gauss_mixture(
        &GaussMixtureCfg { n_train: 48, n_val: 19, dim: 6, classes: 3, ..Default::default() },
        3,
    );
    let mut primary = MockBackend::new();
    let snap_before = Arc::new(primary.export_snapshot(SnapshotTier::Params).unwrap());
    let (ref_acc, ref_loss) = {
        let val_order: Vec<u32> = (0..tv.val.n as u32).collect();
        let mut sink = EvalSink::default();
        let mut eng = Engine::new(&tv.val, B);
        eng.run(&mut primary, &tv.val, &val_order, None, StepMode::Forward, &mut sink)
            .unwrap();
        sink.result()
    };
    // train the primary onward; the snapshot must be unaffected
    let order: Vec<u32> = (0..48).collect();
    let mut eng = Engine::new(&tv.train, B);
    let mut sink = EvalSink::default();
    eng.run(&mut primary, &tv.train, &order, None, StepMode::Train { lr: 0.1 }, &mut sink)
        .unwrap();

    let mut lanes = ServiceLanes::spawn(
        primary.replica_builder().unwrap(),
        tv.val.clone(),
        B,
        None,
    )
    .unwrap();
    lanes.submit_eval(0, snap_before).unwrap();
    let events = lanes.drain().unwrap();
    match &events[0] {
        ServiceEvent::Eval { acc, loss, .. } => {
            assert_eq!(acc.to_bits(), ref_acc.to_bits());
            assert_eq!(loss.to_bits(), ref_loss.to_bits());
        }
        other => panic!("unexpected event {other:?}"),
    }
}

/// A failing checkpoint job folds back as a *named*
/// [`ServiceEvent::Error`] at its generation's fold-in slot — without
/// disturbing the eval lane's bitwise results — and the checkpoint lane
/// survives to serialize the next generation.  (Under `--fault-policy
/// fail` the trainer aborts on this event; under `elastic` it counts it
/// into `EpochRecord::service_errors` and continues.)
#[test]
fn checkpoint_failure_folds_as_named_error_without_disturbing_eval() {
    let tv = gauss_mixture(
        &GaussMixtureCfg { n_train: 64, n_val: 23, dim: 6, classes: 3, ..Default::default() },
        17,
    );
    let mut primary = MockBackend::new();
    let order: Vec<u32> = (0..64).collect();
    let mut sink = EvalSink::default();
    let mut eng = Engine::new(&tv.train, B);
    eng.run(&mut primary, &tv.train, &order, None, StepMode::Train { lr: 0.05 }, &mut sink)
        .unwrap();

    // reference: synchronous eval of the trained state
    let val_order: Vec<u32> = (0..tv.val.n as u32).collect();
    let mut sync_sink = EvalSink::default();
    let mut eval_eng = Engine::new(&tv.val, B);
    eval_eng
        .run(&mut primary, &tv.val, &val_order, None, StepMode::Forward, &mut sync_sink)
        .unwrap();
    let (sync_acc, sync_loss) = sync_sink.result();

    let writer: CheckpointWriter = Box::new(|_snap, epoch| {
        anyhow::ensure!(epoch != 0, "disk full writing generation {epoch}");
        Ok(WriteStats::default())
    });
    let mut lanes = ServiceLanes::spawn(
        primary.replica_builder().unwrap(),
        tv.val.clone(),
        B,
        Some(writer),
    )
    .unwrap();
    let snap = Arc::new(primary.export_snapshot(SnapshotTier::Full).unwrap());
    lanes.submit_eval(0, snap.clone()).unwrap();
    lanes.submit_checkpoint(0, snap.clone()).unwrap();
    lanes.submit_checkpoint(1, snap).unwrap();
    let events = lanes.drain().unwrap();
    assert_eq!(events.len(), 3);
    // deterministic fold-in order: epoch-0 eval, epoch-0 checkpoint (the
    // error, sorted where its success event would have landed), epoch 1
    match &events[0] {
        ServiceEvent::Eval { epoch, acc, loss, .. } => {
            assert_eq!(*epoch, 0);
            assert_eq!(acc.to_bits(), sync_acc.to_bits());
            assert_eq!(loss.to_bits(), sync_loss.to_bits());
        }
        other => panic!("unexpected event {other:?}"),
    }
    match &events[1] {
        ServiceEvent::Error { epoch, lane, message, .. } => {
            assert_eq!(*epoch, 0);
            assert_eq!(*lane, ServiceLaneKind::Checkpoint);
            assert!(message.contains("disk full"), "unnamed error: {message}");
        }
        other => panic!("unexpected event {other:?}"),
    }
    match &events[2] {
        ServiceEvent::Checkpoint { epoch, .. } => assert_eq!(*epoch, 1),
        other => panic!("unexpected event {other:?}"),
    }
}

// --- trainer-level (PJRT; skipped when artifacts are absent) -------------

fn runtime() -> Option<XlaRuntime> {
    XlaRuntime::new(&default_artifacts_dir()).ok()
}

fn small_cfg() -> kakurenbo::config::ExperimentConfig {
    let mut cfg = presets::by_name("cifar100_wrn").unwrap();
    cfg.epochs = 5;
    if let DatasetConfig::GaussMixture(ref mut c) = cfg.dataset {
        c.n_train = 512;
        c.n_val = 192;
    }
    cfg.eval_every = 1;
    cfg.strategy = StrategyConfig::kakurenbo(0.3);
    cfg
}

fn tmp_dir(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("kakurenbo_svc_{name}_{}", std::process::id()))
}

/// With `--service-lane on`, the per-epoch RunResult (loss curves, val
/// accuracy, hidden counts) is bitwise identical to `off`, the final
/// parameters match bit for bit, and the checkpoints written by the two
/// paths are byte-identical.
#[test]
fn service_lane_run_is_bitwise_identical_to_sync_run() {
    let Some(rt) = runtime() else { return };
    let dir_off = tmp_dir("off");
    let dir_on = tmp_dir("on");
    std::fs::remove_dir_all(&dir_off).ok();
    std::fs::remove_dir_all(&dir_on).ok();

    let run = |on: bool| {
        let mut cfg = small_cfg();
        cfg.service_lane = on;
        cfg.checkpoint_every = 2;
        cfg.checkpoint_dir = Some(if on { dir_on.clone() } else { dir_off.clone() });
        let mut t = Trainer::new(&rt, cfg).unwrap();
        let result = t.run().unwrap();
        let params = t.exec.export_named_params().unwrap();
        (result, params)
    };
    let (r_off, p_off) = run(false);
    let (r_on, p_on) = run(true);

    assert_eq!(r_off.records.len(), r_on.records.len());
    for (a, b) in r_off.records.iter().zip(&r_on.records) {
        assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits(), "epoch {}", a.epoch);
        assert_eq!(a.val_acc.to_bits(), b.val_acc.to_bits(), "epoch {}", a.epoch);
        assert_eq!(a.val_loss.to_bits(), b.val_loss.to_bits(), "epoch {}", a.epoch);
        assert_eq!(a.hidden, b.hidden, "epoch {}", a.epoch);
        assert_eq!(a.hidden_again, b.hidden_again, "epoch {}", a.epoch);
        assert_eq!(a.moved_back, b.moved_back, "epoch {}", a.epoch);
        assert_eq!(a.trained_samples, b.trained_samples, "epoch {}", a.epoch);
        assert_eq!(a.lr.to_bits(), b.lr.to_bits(), "epoch {}", a.epoch);
    }
    assert_eq!(r_off.final_acc.to_bits(), r_on.final_acc.to_bits());
    assert_eq!(r_off.best_acc.to_bits(), r_on.best_acc.to_bits());
    // async epochs report the lane's off-path seconds
    assert!(r_on.records.iter().any(|r| r.time_service > 0.0));

    // final parameters bit for bit
    assert_eq!(p_off.len(), p_on.len());
    for ((na, da), (nb, db)) in p_off.iter().zip(&p_on) {
        assert_eq!(na, nb);
        let ba: Vec<u32> = da.iter().map(|v| v.to_bits()).collect();
        let bb: Vec<u32> = db.iter().map(|v| v.to_bits()).collect();
        assert_eq!(ba, bb, "param {na} differs");
    }

    // checkpoints byte-identical (the lane serialized an exact snapshot)
    let mut names: Vec<_> = std::fs::read_dir(&dir_off)
        .unwrap()
        .map(|e| e.unwrap().file_name())
        .collect();
    names.sort();
    assert!(!names.is_empty());
    for name in names {
        let a = std::fs::read(dir_off.join(&name)).unwrap();
        let b = std::fs::read(dir_on.join(&name)).unwrap();
        assert_eq!(a, b, "checkpoint file {name:?} differs");
    }
    std::fs::remove_dir_all(&dir_off).ok();
    std::fs::remove_dir_all(&dir_on).ok();
}

/// The service lane composes with the worker pool's data-parallel
/// schedule: `--workers 2 --dp average --service-lane on` reproduces the
/// sync run's records bitwise.
#[test]
fn service_lane_composes_with_dp_average() {
    let Some(rt) = runtime() else { return };
    let run = |on: bool| {
        let mut cfg = small_cfg();
        cfg.workers = 2;
        cfg.dp = kakurenbo::config::DpMode::Average;
        cfg.service_lane = on;
        Trainer::new(&rt, cfg).unwrap().run().unwrap()
    };
    let r_off = run(false);
    let r_on = run(true);
    for (a, b) in r_off.records.iter().zip(&r_on.records) {
        assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits(), "epoch {}", a.epoch);
        assert_eq!(a.val_acc.to_bits(), b.val_acc.to_bits(), "epoch {}", a.epoch);
        assert_eq!(a.hidden, b.hidden, "epoch {}", a.epoch);
    }
}
