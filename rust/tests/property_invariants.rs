//! Property-based tests (mini-proptest harness, util::proptest) over the
//! coordinator's invariants: hiding selector, schedules, samplers,
//! sharding, the worker pool's deterministic reduction, DropTop, the
//! LR rule, and the JSON wire format the inference lane serves over.

use std::collections::BTreeMap;

use kakurenbo::data::shard::{
    global_batch_order, global_step_order, shard_order, shard_order_aligned,
};
use kakurenbo::hiding::droptop::drop_top;
use kakurenbo::hiding::fraction::FractionSchedule;
use kakurenbo::hiding::lr::adjusted_lr;
use kakurenbo::hiding::selector::{select, SelectMode, SelectorCfg};
use kakurenbo::sampler::alias::AliasTable;
use kakurenbo::sampler::fenwick::FenwickSampler;
use kakurenbo::state::SampleState;
use kakurenbo::util::json::{parse, Json};
use kakurenbo::util::proptest::{check, Gen, Pair, USize, VecF32};
use kakurenbo::util::rng::Rng;

/// Random SampleState generator (losses + PA/PC flags).
struct StateGen {
    max_n: usize,
}

#[derive(Clone, Debug)]
struct StateCase {
    losses: Vec<f32>,
    correct: Vec<bool>,
    conf: Vec<f32>,
}

impl Gen for StateGen {
    type Value = StateCase;

    fn generate(&self, rng: &mut Rng) -> StateCase {
        let n = 1 + rng.below(self.max_n);
        StateCase {
            losses: (0..n).map(|_| rng.f32() * 12.0).collect(),
            correct: (0..n).map(|_| rng.chance(0.6)).collect(),
            conf: (0..n).map(|_| rng.f32()).collect(),
        }
    }

    fn shrink(&self, v: &StateCase) -> Vec<StateCase> {
        if v.losses.len() <= 1 {
            return vec![];
        }
        let h = v.losses.len() / 2;
        vec![StateCase {
            losses: v.losses[..h].to_vec(),
            correct: v.correct[..h].to_vec(),
            conf: v.conf[..h].to_vec(),
        }]
    }
}

fn build_state(c: &StateCase) -> SampleState {
    let mut s = SampleState::new(c.losses.len());
    for i in 0..c.losses.len() {
        s.record(i, c.losses[i], c.correct[i], c.conf[i], 0);
    }
    s
}

#[test]
fn selector_partitions_and_respects_ceiling() {
    check("selector-partition", 11, 150, &StateGen { max_n: 300 }, |case| {
        let state = build_state(case);
        let n = case.losses.len();
        for f in [0.0, 0.13, 0.3, 0.77, 0.999] {
            let sel = select(&state, f, &SelectorCfg::default());
            let mut all: Vec<u32> = sel.train.iter().chain(&sel.hidden).copied().collect();
            all.sort_unstable();
            if all != (0..n as u32).collect::<Vec<_>>() {
                return Err(format!("not a partition at f={f}"));
            }
            if sel.hidden.len() > (n as f64 * f).floor() as usize {
                return Err(format!("ceiling exceeded at f={f}"));
            }
            // every hidden sample satisfies the MB predicate
            for &h in &sel.hidden {
                let i = h as usize;
                if !(case.correct[i] && case.conf[i] >= 0.7) {
                    return Err(format!("hidden sample {i} fails PA/PC rule"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn selector_hidden_losses_dominated_by_train_losses() {
    // max(loss of hidden candidates) <= max loss overall, and hidden set
    // comes from the F*N smallest losses: every hidden loss must be <= the
    // (F*N)-th smallest loss.
    check("selector-order", 13, 100, &StateGen { max_n: 200 }, |case| {
        let state = build_state(case);
        let n = case.losses.len();
        let f = 0.4;
        let k = (n as f64 * f).floor() as usize;
        if k == 0 {
            return Ok(());
        }
        let mut sorted = case.losses.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let kth = sorted[k - 1];
        let sel = select(&state, f, &SelectorCfg::default());
        for &h in &sel.hidden {
            if case.losses[h as usize] > kth {
                return Err(format!(
                    "hidden loss {} above k-th smallest {kth}",
                    case.losses[h as usize]
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn quickselect_and_fullsort_agree() {
    check("select-modes-agree", 17, 100, &StateGen { max_n: 250 }, |case| {
        let state = build_state(case);
        for f in [0.1, 0.5, 0.9] {
            let a = select(&state, f, &SelectorCfg { mode: SelectMode::QuickSelect, ..Default::default() });
            let b = select(&state, f, &SelectorCfg { mode: SelectMode::FullSort, ..Default::default() });
            let mut ha = a.hidden;
            let mut hb = b.hidden;
            ha.sort_unstable();
            hb.sort_unstable();
            if ha != hb {
                return Err(format!("modes disagree at f={f}"));
            }
        }
        Ok(())
    });
}

#[test]
fn fraction_schedule_monotone_and_bounded() {
    check(
        "fraction-monotone",
        3,
        100,
        &Pair(USize { lo: 1, hi: 500 }, USize { lo: 1, hi: 99 }),
        |&(total, f_pct)| {
            let f = f_pct as f64 / 100.0;
            let s = FractionSchedule::paper_default(f, total);
            s.validate().map_err(|e| e.to_string())?;
            let mut prev = f64::INFINITY;
            for e in 0..total {
                let v = s.at(e);
                if v > f + 1e-12 {
                    return Err(format!("F_e {v} above ceiling {f} at {e}"));
                }
                if v > prev + 1e-12 {
                    return Err(format!("non-monotone at {e}"));
                }
                prev = v;
            }
            Ok(())
        },
    );
}

#[test]
fn lr_rule_update_mass_invariant() {
    check("lr-mass", 5, 200, &USize { lo: 0, hi: 99 }, |&f_pct| {
        let f = f_pct as f64 / 100.0;
        let eta = adjusted_lr(0.1, f);
        // (1-F) N steps at eta == N steps at 0.1
        let mass = (1.0 - f) * eta;
        if (mass - 0.1).abs() > 1e-12 {
            return Err(format!("mass {mass}"));
        }
        Ok(())
    });
}

#[test]
fn shard_union_covers_order() {
    check(
        "shard-cover",
        7,
        150,
        &Pair(USize { lo: 1, hi: 2000 }, USize { lo: 1, hi: 17 }),
        |&(n, w)| {
            let order: Vec<u32> = (0..n as u32).rev().collect();
            let shards = shard_order(&order, w);
            // equal sizes
            let sz = shards[0].indices.len();
            if !shards.iter().all(|s| s.indices.len() == sz) {
                return Err("ragged shards".into());
            }
            // union covers all samples
            let mut seen = vec![false; n];
            for s in &shards {
                for &i in &s.indices {
                    seen[i as usize] = true;
                }
            }
            if !seen.iter().all(|&b| b) {
                return Err("missing samples".into());
            }
            // global order has w*sz entries
            if global_step_order(&shards).len() != w * sz {
                return Err("global order size".into());
            }
            Ok(())
        },
    );
}

#[test]
fn aligned_shards_take_equal_whole_steps_and_cover() {
    check(
        "shard-aligned",
        19,
        150,
        &Pair(USize { lo: 0, hi: 600 }, Pair(USize { lo: 1, hi: 9 }, USize { lo: 1, hi: 17 })),
        |&(n, (w, b))| {
            let order: Vec<u32> = (0..n as u32).rev().collect();
            let shards = shard_order_aligned(&order, w, b);
            if shards.len() != w {
                return Err("wrong worker count".into());
            }
            let len = shards[0].len();
            if !shards.iter().all(|s| s.len() == len) {
                return Err("ragged shards".into());
            }
            if len % b != 0 {
                return Err(format!("shard len {len} not a multiple of batch {b}"));
            }
            if n > 0 {
                // every worker takes the same number of *full* steps
                let steps = shards[0].steps(b);
                if !shards.iter().all(|s| s.steps(b) == steps) {
                    return Err("unequal step counts".into());
                }
                // union covers every sample (wrap padding only duplicates)
                let mut seen = vec![false; n];
                for s in &shards {
                    for &i in &s.indices {
                        seen[i as usize] = true;
                    }
                }
                if !seen.iter().all(|&x| x) {
                    return Err("missing samples".into());
                }
                if global_batch_order(&shards, b).len() != w * len {
                    return Err("global batch order size".into());
                }
            }
            Ok(())
        },
    );
}

/// The worker pool's fixed `(step, worker)` reduction must fold stats,
/// sink state, and backend state exactly like the serial interleaved
/// stream — for any (order length, worker count, batch size).
#[test]
fn pool_reduction_matches_serial_interleaved_fold() {
    use kakurenbo::data::synth::{gauss_mixture, GaussMixtureCfg};
    use kakurenbo::engine::testbed::MockBackend;
    use kakurenbo::engine::{Engine, StepMode, TrainSink, WorkerPool};

    let data = gauss_mixture(
        &GaussMixtureCfg { n_train: 160, n_val: 4, dim: 4, classes: 3, ..Default::default() },
        23,
    )
    .train;
    check(
        "pool-serial-fold",
        41,
        40,
        &Pair(USize { lo: 0, hi: 160 }, Pair(USize { lo: 1, hi: 5 }, USize { lo: 1, hi: 12 })),
        |&(n, (w, b))| {
            let order: Vec<u32> = (0..n as u32).collect();
            let shards = shard_order_aligned(&order, w, b);
            let flat = global_batch_order(&shards, b);

            let mut ref_be = MockBackend::new();
            let mut ref_state = SampleState::new(160);
            let mut eng = Engine::new(&data, b);
            eng.overlap = true;
            let mut sink = TrainSink::new(&mut ref_state, 1);
            eng.run(&mut ref_be, &data, &flat, None, StepMode::Train { lr: 0.02 }, &mut sink)
                .map_err(|e| e.to_string())?;
            let ref_loss = sink.mean_loss();

            let mut be = MockBackend::new();
            let mut state = SampleState::new(160);
            let mut pool = WorkerPool::new(&data, b);
            let mut sink = TrainSink::new(&mut state, 1);
            let mode = StepMode::Train { lr: 0.02 };
            pool.run_serial_equivalent(&mut be, &data, &shards, mode, &mut sink)
                .map_err(|e| e.to_string())?;
            let pool_loss = sink.mean_loss();

            if ref_be.param.to_bits() != be.param.to_bits() {
                return Err(format!("param diverged (n={n} w={w} b={b})"));
            }
            if ref_be.trace != be.trace {
                return Err(format!("trace diverged (n={n} w={w} b={b})"));
            }
            if ref_loss.to_bits() != pool_loss.to_bits() {
                return Err(format!("loss diverged (n={n} w={w} b={b})"));
            }
            let bits = |s: &SampleState| -> Vec<u32> {
                s.loss.iter().map(|l| l.to_bits()).collect()
            };
            if bits(&ref_state) != bits(&state) {
                return Err(format!("state diverged (n={n} w={w} b={b})"));
            }
            Ok(())
        },
    );
}

/// Elastic-recovery invariants (docs/worker-model.md, "Fault tolerance"):
/// for random (order length, worker count, kill point) triples,
/// (a) `reissue_tail` re-issues every remaining batch slot of the dead
/// shard exactly once, in step order, round-robin across the recovery
/// lanes, and (b) an elastic chaos-kill pool run folds bitwise
/// identically to the undisturbed run — since both fold onto one backend
/// in `(step, worker)` order, trace equality proves the recovered order
/// is a permutation-free replay of the undisturbed order.
#[test]
fn elastic_reissue_covers_exactly_once_and_folds_bitwise() {
    use kakurenbo::data::shard::reissue_tail;
    use kakurenbo::data::synth::{gauss_mixture, GaussMixtureCfg};
    use kakurenbo::engine::testbed::MockBackend;
    use kakurenbo::engine::{ChaosPlan, EvalSink, StepMode, WorkerPool};

    let data = gauss_mixture(
        &GaussMixtureCfg { n_train: 160, n_val: 4, dim: 4, classes: 3, ..Default::default() },
        29,
    )
    .train;
    let b = 8;
    check(
        "elastic-reissue",
        43,
        25,
        &Pair(USize { lo: 1, hi: 160 }, Pair(USize { lo: 2, hi: 4 }, USize { lo: 0, hi: 62 })),
        |&(n, (w, r))| {
            let order: Vec<u32> = (0..n as u32).rev().collect();
            let shards = shard_order_aligned(&order, w, b);
            let steps = shards[0].steps(b);
            if steps == 0 {
                return Ok(());
            }
            let victim = r % w;
            let kill_step = (r / w) % steps;

            // (a) exactly-once coverage of the dead shard's tail, in step
            // order, on round-robin recovery lanes.
            let survivors = w - 1;
            let slices = reissue_tail(&shards[victim], kill_step, b, survivors);
            if slices.len() != steps - kill_step {
                return Err(format!(
                    "{} slices for {} remaining steps (n={n} w={w} kill={kill_step})",
                    slices.len(),
                    steps - kill_step
                ));
            }
            let mut got: Vec<u32> = Vec::new();
            for (i, sl) in slices.iter().enumerate() {
                let t = kill_step + i;
                if sl.step != t {
                    return Err(format!("slice {i} carries step {} expected {t}", sl.step));
                }
                if sl.lane != (t - kill_step) % survivors.max(1) {
                    return Err(format!("slice {i} on lane {} breaks round-robin", sl.lane));
                }
                got.extend_from_slice(&sl.indices);
            }
            let mut expect: Vec<u32> = Vec::new();
            for t in kill_step..steps {
                expect.extend_from_slice(shards[victim].step_batch(t, b));
            }
            if got != expect {
                return Err(format!("re-issued slots differ (n={n} w={w} kill={kill_step})"));
            }

            // (b) elastic kill-recovery run folds bitwise identically.
            let mode = StepMode::Train { lr: 0.02 };
            let mut ref_be = MockBackend::new();
            let mut ref_sink = EvalSink::default();
            let mut pool = WorkerPool::new(&data, b);
            pool.run_serial_equivalent(&mut ref_be, &data, &shards, mode, &mut ref_sink)
                .map_err(|e| e.to_string())?;

            let mut be = MockBackend::new();
            let mut sink = EvalSink::default();
            let mut pool = WorkerPool::new(&data, b);
            pool.set_fault_policy(true, 0);
            pool.inject_chaos(ChaosPlan::new().kill(victim, kill_step));
            let out = pool
                .run_serial_equivalent(&mut be, &data, &shards, mode, &mut sink)
                .map_err(|e| e.to_string())?;

            if out.dropped_lanes != 1 || out.rejoined_lanes != 1 {
                return Err(format!(
                    "dropped {} rejoined {} expected 1/1",
                    out.dropped_lanes, out.rejoined_lanes
                ));
            }
            if ref_be.param.to_bits() != be.param.to_bits() {
                return Err(format!("param diverged (n={n} w={w} kill={kill_step}@{victim})"));
            }
            if ref_be.trace != be.trace {
                return Err(format!("fold order diverged (n={n} w={w} kill={kill_step}@{victim})"));
            }
            let (ra, rl) = ref_sink.result();
            let (ca, cl) = sink.result();
            if ra.to_bits() != ca.to_bits() || rl.to_bits() != cl.to_bits() {
                return Err(format!("sink diverged (n={n} w={w} kill={kill_step}@{victim})"));
            }
            Ok(())
        },
    );
}

#[test]
fn droptop_drops_exactly_top_fraction() {
    check("droptop", 23, 150, &StateGen { max_n: 300 }, |case| {
        let state = build_state(case);
        let n = case.losses.len();
        let train: Vec<u32> = (0..n as u32).collect();
        let (kept, dropped) = drop_top(&state, &train, 0.1);
        let k = (n as f64 * 0.1).floor() as usize;
        if dropped.len() != k {
            return Err(format!("dropped {} expected {k}", dropped.len()));
        }
        if kept.len() + dropped.len() != n {
            return Err("partition broken".into());
        }
        let max_kept = kept
            .iter()
            .map(|&i| case.losses[i as usize])
            .fold(f32::NEG_INFINITY, f32::max);
        let min_dropped = dropped
            .iter()
            .map(|&i| case.losses[i as usize])
            .fold(f32::INFINITY, f32::min);
        if !dropped.is_empty() && min_dropped < max_kept - 1e-6 {
            return Err(format!("dropped {min_dropped} < kept {max_kept}"));
        }
        Ok(())
    });
}

#[test]
fn alias_table_unbiased_on_random_weights() {
    check("alias-unbiased", 29, 12, &VecF32 { min_len: 2, max_len: 40, lo: 0.0, hi: 5.0 }, |ws| {
        let weights: Vec<f64> = ws.iter().map(|&w| w as f64).collect();
        let total: f64 = weights.iter().sum();
        if total <= 0.0 {
            return Ok(());
        }
        let table = AliasTable::new(&weights);
        let mut rng = Rng::new(77);
        let draws = 60_000;
        let mut counts = vec![0usize; weights.len()];
        for _ in 0..draws {
            counts[table.draw(&mut rng) as usize] += 1;
        }
        for (i, &w) in weights.iter().enumerate() {
            let expect = w / total;
            let got = counts[i] as f64 / draws as f64;
            if (got - expect).abs() > 0.02 {
                return Err(format!("i={i} got {got:.3} expect {expect:.3}"));
            }
        }
        Ok(())
    });
}

#[test]
fn fenwick_matches_alias_distribution() {
    check("fenwick-alias", 31, 8, &VecF32 { min_len: 2, max_len: 30, lo: 0.1, hi: 3.0 }, |ws| {
        let weights: Vec<f64> = ws.iter().map(|&w| w as f64).collect();
        let total: f64 = weights.iter().sum();
        let fen = FenwickSampler::new(&weights);
        let mut rng = Rng::new(123);
        let draws = 40_000;
        let mut counts = vec![0usize; weights.len()];
        for _ in 0..draws {
            counts[fen.draw(&mut rng).unwrap() as usize] += 1;
        }
        for (i, &w) in weights.iter().enumerate() {
            let expect = w / total;
            let got = counts[i] as f64 / draws as f64;
            if (got - expect).abs() > 0.025 {
                return Err(format!("i={i} got {got:.3} expect {expect:.3}"));
            }
        }
        Ok(())
    });
}

#[test]
fn state_roll_epoch_preserves_counts() {
    check("state-roll", 37, 100, &USize { lo: 1, hi: 500 }, |&n| {
        let mut s = SampleState::new(n);
        let mut rng = Rng::new(n as u64);
        let k = rng.below(n + 1);
        let hidden: Vec<u32> = rng.sample_indices(n, k);
        s.set_hidden(&hidden);
        if s.hidden_count() != k {
            return Err("hidden count".into());
        }
        s.roll_epoch();
        if s.hidden_count() != 0 {
            return Err("roll didn't clear".into());
        }
        // hiding the same set again: hidden_again == k
        s.set_hidden(&hidden);
        if s.hidden_again_count() != k {
            return Err("hidden_again mismatch".into());
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// JSON wire format (util::json) — the serving endpoints ride on it, so the
// encoder/parser pair must round-trip bit-exactly and reject garbage with a
// position instead of panicking or silently absorbing it.
// ---------------------------------------------------------------------------

/// Random JSON documents: depth-bounded trees over every value kind, with
/// adversarial finite numbers and strings full of escape-worthy characters.
struct JsonGen {
    max_depth: usize,
}

fn json_num(rng: &mut Rng) -> f64 {
    const POOL: [f64; 12] = [
        0.0,
        -0.0,
        5e-324, // smallest denormal
        2.2250738585072011e-308,
        f64::MIN_POSITIVE,
        1e300,
        -1e300,
        f64::MAX,
        f64::MIN,
        1e15, // just past the integral fast path
        0.1,
        0.333_333_333_333_333_3,
    ];
    match rng.below(4) {
        0 => rng.below(2_000_001) as f64 - 1_000_000.0,
        1 => (rng.f64() - 0.5) * 100.0,
        2 => POOL[rng.below(POOL.len())],
        // random mantissa over ~600 decades, always finite
        _ => (rng.f64() - 0.5) * 10f64.powi(rng.below(601) as i32 - 300),
    }
}

fn json_str(rng: &mut Rng) -> String {
    const CHARS: [char; 16] = [
        'a', 'B', '7', ' ', '"', '\\', '/', '\n', '\t', '\r', '\u{8}', '\u{c}', '\u{1}', 'é',
        '→', '🦀',
    ];
    (0..rng.below(9)).map(|_| CHARS[rng.below(CHARS.len())]).collect()
}

fn json_value(rng: &mut Rng, depth: usize) -> Json {
    if depth == 0 || rng.chance(0.45) {
        return match rng.below(4) {
            0 => Json::Null,
            1 => Json::Bool(rng.chance(0.5)),
            2 => Json::Num(json_num(rng)),
            _ => Json::Str(json_str(rng)),
        };
    }
    if rng.chance(0.5) {
        Json::Arr((0..rng.below(5)).map(|_| json_value(rng, depth - 1)).collect())
    } else {
        let mut m = BTreeMap::new();
        for _ in 0..rng.below(5) {
            m.insert(json_str(rng), json_value(rng, depth - 1));
        }
        Json::Obj(m)
    }
}

impl Gen for JsonGen {
    type Value = Json;

    fn generate(&self, rng: &mut Rng) -> Json {
        json_value(rng, self.max_depth)
    }

    fn shrink(&self, v: &Json) -> Vec<Json> {
        // a failing container usually fails through one child: offer each
        // child alone, then the container with the back half removed
        match v {
            Json::Arr(xs) => {
                let mut out = xs.clone();
                out.push(Json::Arr(xs[..xs.len() / 2].to_vec()));
                out
            }
            Json::Obj(m) => {
                let mut out: Vec<Json> = m.values().cloned().collect();
                let half: BTreeMap<String, Json> =
                    m.iter().take(m.len() / 2).map(|(k, x)| (k.clone(), x.clone())).collect();
                out.push(Json::Obj(half));
                out
            }
            _ => Vec::new(),
        }
    }
}

#[test]
fn json_roundtrip_is_byte_stable() {
    check("json-roundtrip", 73, 400, &JsonGen { max_depth: 4 }, |v| {
        let compact = v.to_compact();
        let back = parse(&compact).map_err(|e| format!("{compact:?}: {e}"))?;
        let again = back.to_compact();
        if again != compact {
            return Err(format!("re-encode drifted: {compact:?} -> {again:?}"));
        }
        if back != *v {
            return Err(format!("value changed through the wire: {compact:?}"));
        }
        // pretty printing is a formatting choice, not a different document
        let pretty = parse(&v.to_pretty()).map_err(|e| format!("pretty: {e}"))?;
        if pretty.to_compact() != compact {
            return Err(format!("pretty roundtrip drifted for {compact:?}"));
        }
        Ok(())
    });
}

/// Corruptions of valid documents: truncation, hostile byte insertion,
/// undefined escapes, and overlong number tails.
struct MalformedGen;

impl Gen for MalformedGen {
    type Value = String;

    fn generate(&self, rng: &mut Rng) -> String {
        let mut s = json_value(rng, 3).to_compact();
        let mut pos = rng.below(s.len() + 1);
        while !s.is_char_boundary(pos) {
            pos -= 1;
        }
        match rng.below(5) {
            0 => s.truncate(pos),
            1 => {
                const HOSTILE: [char; 10] = ['\\', '"', '{', '[', ',', ':', 'e', '-', '.', 'x'];
                s.insert(pos, HOSTILE[rng.below(HOSTILE.len())]);
            }
            2 => s.insert_str(pos, "\\q"),         // escape JSON never defined
            3 => s.push_str("e999999999"),         // overlong exponent / trailing data
            _ => s.insert_str(pos, &"9".repeat(400)), // 400-digit number fragment
        }
        s
    }
}

#[test]
fn json_malformed_inputs_error_with_positions_never_panic() {
    check("json-malformed", 91, 600, &MalformedGen, |s| {
        // some corruptions still form valid JSON; the contract is that
        // parse never panics and every rejection names a source position
        match parse(s) {
            Ok(v) => {
                let _ = v.to_compact();
                Ok(())
            }
            Err(e) if e.line >= 1 && e.col >= 1 && !e.msg.is_empty() => Ok(()),
            Err(e) => Err(format!("unpositioned error {e:?} for {s:?}")),
        }
    });
}

#[test]
fn json_known_hostile_inputs_are_positioned_errors() {
    for src in [
        "",
        "{",
        "[1,",
        "\"ab",
        "\"\\q\"",
        "\"\\u12\"",
        "1e",
        "--5",
        "1.2.3",
        "[1 2]",
        "{\"a\" 1}",
        "nul",
        "+5",
        ".5",
        "01x",
        "1e999",
        "[}",
    ] {
        let e = parse(src).unwrap_err();
        assert!(e.line >= 1 && e.col >= 1, "{src:?} -> {e:?}");
        assert!(!e.msg.is_empty(), "{src:?} produced an empty message");
    }
}
