//! Cross-strategy invariant battery: every strategy in the catalog is
//! driven through a full plan -> train -> refresh -> harvest loop on the
//! deterministic [`MockBackend`] and checked, every epoch, against the
//! contracts the coordinator relies on:
//!
//! - hidden/pruned counts never exceed the strategy's own
//!   `fraction_ceiling` (InfoBatch, whose ceiling is an expectation, is
//!   instead held to its exact invariant: pruned samples are below the
//!   pre-plan mean loss);
//! - the hidden list is disjoint from the trained order and every entry
//!   is marked in `SampleState` (`hidden_count` matches a full scan);
//! - `pruned_pre_forward` is claimed only by cached-feature pruning
//!   (PFB), where it equals the hidden count;
//! - the whole loop replays bitwise identically under a fixed seed.
//!
//! Executor-backed strategies (EL2N at its score epoch, GradMatch at its
//! selection epochs) cannot plan without a PJRT `fwd_embed` artifact;
//! the battery exercises their executor-free epochs and pins the
//! documented error they must raise otherwise.
//!
//! The final test pins PFB's device-call budget: epochs that reuse the
//! feature cache perform ZERO extra device forwards — only train steps —
//! while harvest epochs pay exactly one embedding sweep.

use kakurenbo::config::StrategyConfig;
use kakurenbo::data::synth::{gauss_mixture, GaussMixtureCfg};
use kakurenbo::data::{Dataset, TrainVal};
use kakurenbo::engine::testbed::MockBackend;
use kakurenbo::engine::{execute_feature_harvest, Engine, RefreshSink, StepMode, TrainSink};
use kakurenbo::state::{FeatureCache, SampleState};
use kakurenbo::strategies::{build, EpochPlan, PlanCtx};
use kakurenbo::util::rng::Rng;

const N: usize = 48;
const BATCH: usize = 8;
const EPOCHS: usize = 8;
const LR: f32 = 0.05;

fn tiny() -> TrainVal {
    gauss_mixture(
        &GaussMixtureCfg { n_train: N, n_val: 16, dim: 6, classes: 3, ..Default::default() },
        11,
    )
}

/// Every strategy that can plan all of `0..EPOCHS` without an executor.
/// EL2N's score epoch sits beyond the horizon so its (plain) prologue
/// epochs run here; its in-horizon behavior is pinned separately below,
/// as is GradMatch (which selects from epoch 1 on).
fn catalog() -> Vec<StrategyConfig> {
    vec![
        StrategyConfig::Baseline,
        StrategyConfig::kakurenbo(0.3),
        StrategyConfig::Iswr,
        StrategyConfig::SelectiveBackprop { beta: 1.0 },
        StrategyConfig::Forget { prune_epoch: 4, fraction: 0.25 },
        StrategyConfig::RandomHiding { fraction: 0.2 },
        StrategyConfig::InfoBatch { r: 0.5 },
        StrategyConfig::El2n { score_epoch: EPOCHS + 2, fraction: 0.15, restart: false },
        StrategyConfig::Pfb { fraction: 0.25, refresh_every: 3 },
    ]
}

/// Everything one epoch decided, reduced to bit patterns for exact
/// replay comparison.
#[derive(Debug, PartialEq)]
struct EpochTrace {
    order: Vec<u32>,
    weight_bits: Option<Vec<u32>>,
    hidden: Vec<u32>,
    lr_scale_bits: u64,
    pruned_pre_forward: usize,
}

/// Full-run outcome: per-epoch decisions plus the backend's bit-exact
/// parameter history and the final per-sample loss store.
#[derive(Debug, PartialEq)]
struct Sim {
    epochs: Vec<EpochTrace>,
    param_bits: u32,
    step_trace: Vec<u64>,
    loss_bits: Vec<u32>,
}

fn check_invariants(
    name: &str,
    epoch: usize,
    ceiling: f64,
    plan: &EpochPlan,
    state: &SampleState,
    loss_before: &[f32],
) {
    let tag = format!("{name} epoch {epoch}");
    let cap = (N as f64 * ceiling).floor() as usize;

    // Index sanity: everything addresses a real sample, hidden is a set.
    assert!(plan.order.iter().all(|&i| (i as usize) < N), "{tag}: order out of range");
    assert!(plan.hidden.iter().all(|&i| (i as usize) < N), "{tag}: hidden out of range");
    let mut is_hidden = vec![false; N];
    for &h in &plan.hidden {
        assert!(!is_hidden[h as usize], "{tag}: duplicate hidden sample {h}");
        is_hidden[h as usize] = true;
    }

    // Hidden never trains this epoch.
    assert!(
        plan.order.iter().all(|&i| !is_hidden[i as usize]),
        "{tag}: hidden sample appears in train order"
    );

    // Ceiling: hard cap from the strategy's own fraction_ceiling.
    // InfoBatch prunes below-mean samples with probability r, so its
    // exact invariant is membership (below the pre-plan mean), not a
    // deterministic count bound.
    if name == "infobatch" {
        let finite: Vec<f32> = loss_before.iter().copied().filter(|l| l.is_finite()).collect();
        let mean = finite.iter().map(|&l| l as f64).sum::<f64>() / finite.len().max(1) as f64;
        for &h in &plan.hidden {
            assert!(
                (loss_before[h as usize] as f64) < mean,
                "{tag}: pruned above-mean sample {h}"
            );
        }
    } else {
        assert!(
            plan.hidden.len() <= cap,
            "{tag}: {} hidden > ceiling {cap} (F_e={ceiling})",
            plan.hidden.len()
        );
        assert!(
            plan.max_hidden <= cap,
            "{tag}: {} candidates > ceiling {cap}",
            plan.max_hidden
        );
    }

    // Coverage: samples neither trained nor hidden are bounded by the
    // same ceiling (permanent pruners like FORGET/EL2N shrink the order
    // instead of filling `hidden`).  ISWR draws with replacement, so its
    // per-epoch distinct coverage is genuinely random — skip it.
    if name != "iswr" {
        let mut touched = vec![false; N];
        for &i in &plan.order {
            touched[i as usize] = true;
        }
        for &h in &plan.hidden {
            touched[h as usize] = true;
        }
        let untouched = touched.iter().filter(|&&t| !t).count();
        assert!(untouched <= cap, "{tag}: {untouched} untouched samples > ceiling {cap}");
    }

    // State marks agree with the plan, and the O(1) counter agrees with
    // a full scan of the flags.
    let scan = state.hidden.iter().filter(|&&h| h).count();
    assert_eq!(state.hidden_count(), scan, "{tag}: hidden_count drifted from flag scan");
    for &h in &plan.hidden {
        assert!(state.hidden[h as usize], "{tag}: hidden sample {h} not marked in state");
    }

    // Pre-forward pruning is PFB's claim alone, and there it must cover
    // the whole hidden list (the plan came from cached scores).
    if name == "pfb" {
        assert_eq!(plan.pruned_pre_forward, plan.hidden.len(), "{tag}: pfb pre-forward count");
    } else {
        assert_eq!(plan.pruned_pre_forward, 0, "{tag}: non-PFB claims pre-forward pruning");
    }

    // LR compensation only ever scales up (Eq. 8), and weights are
    // positive finite per-position multipliers.
    assert!(
        plan.lr_scale.is_finite() && plan.lr_scale >= 1.0,
        "{tag}: lr_scale {}",
        plan.lr_scale
    );
    if let Some(w) = &plan.weights {
        assert_eq!(w.len(), plan.order.len(), "{tag}: weights misaligned with order");
        assert!(w.iter().all(|&x| x.is_finite() && x > 0.0), "{tag}: non-positive weight");
    }
}

/// Drive one strategy through the full coordinator-shaped loop, checking
/// invariants at every epoch.  SB's candidate stream is plain-trained
/// here (the invariants under test are plan-level; its accept-queue
/// semantics have their own tests).
fn simulate(cfg: &StrategyConfig, seed: u64) -> Sim {
    let tv = tiny();
    let data: &Dataset = &tv.train;
    let mut strat = build(cfg, EPOCHS);
    let mut state = SampleState::new(N);
    let mut cache = FeatureCache::new(N);
    let mut rng = Rng::new(seed);
    let mut backend = MockBackend::new();
    let mut engine = Engine::new(data, BATCH);
    let mut epochs = Vec::new();

    for epoch in 0..EPOCHS {
        let loss_before = state.loss.clone();
        let plan = {
            let mut ctx = PlanCtx {
                epoch,
                total_epochs: EPOCHS,
                data,
                state: &mut state,
                rng: &mut rng,
                exec: None,
                features: Some(&cache),
            };
            strat.plan_epoch(&mut ctx).expect("plan_epoch")
        };
        if plan.reset_params {
            // mirror the coordinator: restart parameters, and drop any
            // feature rows harvested from the discarded model
            backend.param = 1.0;
            cache.invalidate();
        }
        let ceiling = strat.fraction_ceiling(epoch);
        check_invariants(&strat.name(), epoch, ceiling, &plan, &state, &loss_before);

        let mut sink = TrainSink::new(&mut state, epoch as u32);
        engine
            .run(
                &mut backend,
                data,
                &plan.order,
                plan.weights.as_deref(),
                StepMode::Train { lr: LR },
                &mut sink,
            )
            .expect("train");

        if strat.refresh_hidden_stats() && !plan.hidden.is_empty() {
            let mut sink = RefreshSink::new(&mut state, epoch as u32);
            engine
                .run(&mut backend, data, &plan.hidden, None, StepMode::Forward, &mut sink)
                .expect("refresh");
        }

        if let Some(every) = strat.feature_refresh_every() {
            let e = epoch as u32;
            if !cache.ready() || cache.age(e) >= every {
                let all: Vec<u32> = (0..N as u32).collect();
                execute_feature_harvest(&mut engine, &mut backend, data, &all, e, &mut state, &mut cache)
                    .expect("harvest");
            }
        }

        epochs.push(EpochTrace {
            order: plan.order,
            weight_bits: plan.weights.map(|w| w.iter().map(|x| x.to_bits()).collect()),
            hidden: plan.hidden,
            lr_scale_bits: plan.lr_scale.to_bits(),
            pruned_pre_forward: plan.pruned_pre_forward,
        });
    }

    Sim {
        epochs,
        param_bits: backend.param.to_bits(),
        step_trace: backend.trace,
        loss_bits: state.loss.iter().map(|l| l.to_bits()).collect(),
    }
}

#[test]
fn invariants_hold_for_every_strategy_every_epoch() {
    for cfg in &catalog() {
        simulate(cfg, 42); // asserts inside check_invariants
    }
}

/// Plan -> train -> refresh -> harvest round-trips are a pure function
/// of (config, seed): two independent replays agree on every order,
/// weight bit, hidden list, parameter bit, and loss bit.
#[test]
fn full_loop_replays_bitwise_under_fixed_seed() {
    for cfg in &catalog() {
        let a = simulate(cfg, 1234);
        let b = simulate(cfg, 1234);
        assert_eq!(a, b, "{:?} replay diverged", build(cfg, EPOCHS).name());
    }
}

/// Different seeds must actually change the randomized strategies —
/// guards against the harness accidentally ignoring its seed.
#[test]
fn seed_reaches_the_planning_rng() {
    let cfg = StrategyConfig::RandomHiding { fraction: 0.2 };
    let a = simulate(&cfg, 1);
    let b = simulate(&cfg, 2);
    assert_ne!(a.epochs[1].hidden, b.epochs[1].hidden, "seed did not reach planning");
}

fn plan_once(
    strat: &mut dyn kakurenbo::strategies::Strategy,
    epoch: usize,
    data: &Dataset,
    state: &mut SampleState,
) -> anyhow::Result<EpochPlan> {
    let mut rng = Rng::new(7 + 1000 * epoch as u64);
    let mut ctx = PlanCtx {
        epoch,
        total_epochs: EPOCHS,
        data,
        state,
        rng: &mut rng,
        exec: None,
        features: None,
    };
    strat.plan_epoch(&mut ctx)
}

/// GradMatch trains plain at epoch 0, then must refuse to select without
/// `fwd_embed` access — with the documented error, not a panic.
#[test]
fn gradmatch_without_executor_reports_documented_error() {
    let tv = tiny();
    let mut state = SampleState::new(N);
    let mut strat = build(&StrategyConfig::GradMatch { fraction: 0.3, every_r: 3 }, EPOCHS);
    let p0 = plan_once(&mut *strat, 0, &tv.train, &mut state).expect("epoch 0 is plain");
    assert_eq!(p0.order.len(), N);
    let err = plan_once(&mut *strat, 1, &tv.train, &mut state).unwrap_err();
    assert!(
        err.to_string().contains("executor access"),
        "undocumented error: {err}"
    );
}

/// EL2N trains plain through its prologue, then must refuse to score
/// without `fwd_embed` access — with the documented error.
#[test]
fn el2n_without_executor_reports_documented_error() {
    let tv = tiny();
    let mut state = SampleState::new(N);
    let mut strat =
        build(&StrategyConfig::El2n { score_epoch: 2, fraction: 0.2, restart: false }, EPOCHS);
    for epoch in 0..2 {
        let p = plan_once(&mut *strat, epoch, &tv.train, &mut state).expect("prologue is plain");
        assert_eq!(p.order.len(), N, "epoch {epoch}");
    }
    let err = plan_once(&mut *strat, 2, &tv.train, &mut state).unwrap_err();
    assert!(
        err.to_string().contains("executor access"),
        "undocumented error: {err}"
    );
}

/// PFB's device-call budget, per epoch:
///
/// - harvest epochs (0, N, 2N, ...) pay exactly one embedding sweep over
///   the dataset (`fwd_embed` batches) on top of their train steps;
/// - every cache-reuse epoch performs ZERO extra device forwards — the
///   plan prunes from cached scores alone, and `fwd_stats` is never
///   called at all (PFB opts out of the hidden-stat refresh because the
///   harvest sweep already refreshes every sample's stats).
#[test]
fn pfb_cache_reuse_epochs_cost_zero_extra_forwards() {
    const EVERY: usize = 3;
    const FRACTION: f64 = 0.25;
    let tv = tiny();
    let data: &Dataset = &tv.train;
    let mut strat = build(&StrategyConfig::Pfb { fraction: FRACTION, refresh_every: EVERY }, EPOCHS);
    let mut state = SampleState::new(N);
    let mut cache = FeatureCache::new(N);
    let mut rng = Rng::new(99);
    let mut backend = MockBackend::new();
    let mut engine = Engine::new(data, BATCH);
    let batches = |len: usize| len.div_ceil(BATCH);
    let k = (N as f64 * FRACTION).floor() as usize;

    for epoch in 0..EPOCHS {
        let plan = {
            let mut ctx = PlanCtx {
                epoch,
                total_epochs: EPOCHS,
                data,
                state: &mut state,
                rng: &mut rng,
                exec: None,
                features: Some(&cache),
            };
            strat.plan_epoch(&mut ctx).expect("plan")
        };
        // epoch 0 plans cold (full data); every later epoch scores from
        // the cache and prunes exactly floor(N * fraction) pre-forward
        if epoch == 0 {
            assert_eq!(plan.order.len(), N);
            assert!(plan.hidden.is_empty());
        } else {
            assert_eq!(plan.hidden.len(), k, "epoch {epoch}");
            assert_eq!(plan.pruned_pre_forward, k, "epoch {epoch}");
            assert_eq!(plan.order.len(), N - k, "epoch {epoch}");
        }

        let (train0, fwd0) = (backend.train_calls, backend.forward_calls());
        let mut sink = TrainSink::new(&mut state, epoch as u32);
        engine
            .run(&mut backend, data, &plan.order, None, StepMode::Train { lr: LR }, &mut sink)
            .expect("train");
        assert!(!strat.refresh_hidden_stats(), "PFB must skip the hidden-stat forward pass");

        let every = strat.feature_refresh_every().expect("PFB harvests");
        assert_eq!(every, EVERY);
        let harvest_due = !cache.ready() || cache.age(epoch as u32) >= every;
        assert_eq!(harvest_due, epoch % EVERY == 0, "cadence at epoch {epoch}");
        if harvest_due {
            let all: Vec<u32> = (0..N as u32).collect();
            execute_feature_harvest(
                &mut engine,
                &mut backend,
                data,
                &all,
                epoch as u32,
                &mut state,
                &mut cache,
            )
            .expect("harvest");
        }

        let train_delta = backend.train_calls - train0;
        let fwd_delta = backend.forward_calls() - fwd0;
        assert_eq!(train_delta, batches(plan.order.len()), "train steps at epoch {epoch}");
        if harvest_due {
            assert_eq!(fwd_delta, batches(N), "harvest sweep at epoch {epoch}");
        } else {
            // the acceptance criterion: cache-reuse epochs are free of
            // any non-train device call
            assert_eq!(fwd_delta, 0, "extra device forwards at cache-reuse epoch {epoch}");
        }
    }

    // PFB never uses the plain stats forward at all.
    assert_eq!(backend.fwd_calls, 0, "fwd_stats must never run under PFB");
    // Harvests landed at 0, 3, 6: three sweeps of ceil(N/BATCH) batches.
    assert_eq!(backend.embed_calls, 3 * batches(N));
}
