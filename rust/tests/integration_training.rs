//! Integration: full training runs through the coordinator for every
//! strategy — each must complete, learn, and exhibit its paper-defining
//! behaviour at small scale.

use kakurenbo::config::{presets, DatasetConfig, StrategyConfig};
use kakurenbo::coordinator::Trainer;
use kakurenbo::runtime::{default_artifacts_dir, XlaRuntime};

fn runtime() -> Option<XlaRuntime> {
    XlaRuntime::new(&default_artifacts_dir()).ok()
}

/// Small, fast config used across tests.
fn small_cfg() -> kakurenbo::config::ExperimentConfig {
    let mut cfg = presets::by_name("cifar100_wrn").unwrap();
    cfg.epochs = 6;
    if let DatasetConfig::GaussMixture(ref mut c) = cfg.dataset {
        c.n_train = 768;
        c.n_val = 256;
    }
    cfg.eval_every = 2;
    cfg
}

fn run(strategy: StrategyConfig) -> kakurenbo::metrics::RunResult {
    let rt = runtime().unwrap();
    let mut cfg = small_cfg();
    cfg.strategy = strategy;
    Trainer::new(&rt, cfg).unwrap().run().unwrap()
}

#[test]
fn baseline_learns() {
    if runtime().is_none() { return }
    let r = run(StrategyConfig::Baseline);
    assert_eq!(r.records.len(), 6);
    assert!(r.best_acc > 0.3, "acc {}", r.best_acc);
    // loss decreases
    assert!(r.records.last().unwrap().train_loss < r.records[0].train_loss);
}

#[test]
fn kakurenbo_hides_and_stays_close_to_baseline() {
    if runtime().is_none() { return }
    let b = run(StrategyConfig::Baseline);
    let k = run(StrategyConfig::kakurenbo(0.3));
    // hides samples from epoch 1 on
    assert_eq!(k.records[0].hidden, 0, "epoch 0 must train on everything");
    assert!(k.records[2..].iter().any(|r| r.hidden > 0), "never hid anything");
    // trains on fewer samples in hiding epochs
    let hid = k.records.iter().find(|r| r.hidden > 0).unwrap();
    assert_eq!(hid.trained_samples + hid.hidden, 768);
    // accuracy within a few points of baseline at this tiny scale
    assert!(
        k.best_acc > b.best_acc - 0.08,
        "kakurenbo {} vs baseline {}",
        k.best_acc,
        b.best_acc
    );
    // LR adjustment applied in hiding epochs
    assert!(hid.lr > hid.base_lr);
}

#[test]
fn iswr_trains_full_epochs_with_weights() {
    if runtime().is_none() { return }
    let r = run(StrategyConfig::Iswr);
    for rec in &r.records {
        assert_eq!(rec.trained_samples, 768, "ISWR keeps the epoch size");
        assert_eq!(rec.hidden, 0);
    }
    assert!(r.best_acc > 0.25);
}

#[test]
fn sb_backprops_fewer_samples() {
    if runtime().is_none() { return }
    let r = run(StrategyConfig::SelectiveBackprop { beta: 1.0 });
    let late = &r.records[3..];
    for rec in late {
        assert_eq!(rec.trained_samples, 768); // forward over everything
        assert!(
            rec.backprop_samples < 700,
            "SB should cut backprops, got {}",
            rec.backprop_samples
        );
    }
}

#[test]
fn forget_prunes_and_restarts() {
    if runtime().is_none() { return }
    let rt = runtime().unwrap();
    let mut cfg = small_cfg();
    cfg.epochs = 8;
    cfg.strategy = StrategyConfig::Forget { prune_epoch: 3, fraction: 0.25 };
    let r = Trainer::new(&rt, cfg).unwrap().run().unwrap();
    for e in 0..3 {
        assert_eq!(r.records[e].trained_samples, 768);
    }
    for e in 3..8 {
        assert_eq!(r.records[e].trained_samples, 768 - 192, "epoch {e}");
    }
    // LR schedule restarted: warmup epoch right after the prune
    assert!(r.records[3].base_lr <= r.records[2].base_lr + 1e-12);
}

#[test]
fn gradmatch_selects_weighted_subset() {
    if runtime().is_none() { return }
    let r = run(StrategyConfig::GradMatch { fraction: 0.3, every_r: 2 });
    // epoch 0 full, later epochs ~70%
    assert_eq!(r.records[0].trained_samples, 768);
    for rec in &r.records[1..] {
        assert!(
            rec.trained_samples < 700 && rec.trained_samples > 300,
            "epoch {} trained {}",
            rec.epoch,
            rec.trained_samples
        );
    }
}

#[test]
fn random_hiding_fixed_fraction() {
    if runtime().is_none() { return }
    let r = run(StrategyConfig::RandomHiding { fraction: 0.2 });
    for rec in &r.records[1..] {
        assert_eq!(rec.hidden, (768.0 * 0.2) as usize);
    }
}

#[test]
fn pfb_prunes_pre_forward_from_cached_features() {
    if runtime().is_none() { return }
    let r = run(StrategyConfig::Pfb { fraction: 0.25, refresh_every: 2 });
    // epoch 0 plans cold (cache not yet harvested): full data, no pruning
    assert_eq!(r.records[0].trained_samples, 768);
    assert_eq!(r.records[0].pruned_pre_forward, 0);
    // every scored epoch prunes floor(768 * 0.25) = 192 samples before
    // any forward pass ran on them
    for rec in &r.records[1..] {
        assert_eq!(rec.pruned_pre_forward, 192, "epoch {}", rec.epoch);
        assert_eq!(rec.hidden, 192, "epoch {}", rec.epoch);
        assert_eq!(rec.trained_samples, 768 - 192, "epoch {}", rec.epoch);
    }
    // plan-time cache age cycles with the harvest cadence (harvests land
    // at the refresh phase of epochs 0, 2, 4)
    let ages: Vec<usize> = r.records.iter().map(|rec| rec.feature_cache_age).collect();
    assert_eq!(ages, vec![0, 1, 2, 1, 2, 1]);
}

#[test]
fn deterministic_runs_same_seed() {
    if runtime().is_none() { return }
    let a = run(StrategyConfig::kakurenbo(0.3));
    let b = run(StrategyConfig::kakurenbo(0.3));
    assert_eq!(a.best_acc, b.best_acc);
    assert_eq!(a.final_acc, b.final_acc);
    let ha: Vec<usize> = a.records.iter().map(|r| r.hidden).collect();
    let hb: Vec<usize> = b.records.iter().map(|r| r.hidden).collect();
    assert_eq!(ha, hb);
}

#[test]
fn different_seeds_differ() {
    if runtime().is_none() { return }
    let rt = runtime().unwrap();
    let mut cfg = small_cfg();
    cfg.strategy = StrategyConfig::Baseline;
    let a = Trainer::new(&rt, cfg.clone()).unwrap().run().unwrap();
    cfg.seed = 4242;
    let b = Trainer::new(&rt, cfg).unwrap().run().unwrap();
    assert_ne!(a.final_acc, b.final_acc);
}

#[test]
fn segnet_workload_trains() {
    if runtime().is_none() { return }
    let rt = runtime().unwrap();
    let mut cfg = presets::by_name("deepcam").unwrap();
    cfg.epochs = 4;
    if let DatasetConfig::DeepcamProxy(ref mut c) = cfg.dataset {
        c.n_train = 256;
        c.n_val = 64;
    }
    cfg.strategy = StrategyConfig::kakurenbo(0.3);
    let r = Trainer::new(&rt, cfg).unwrap().run().unwrap();
    assert!(r.records.last().unwrap().train_loss < r.records[0].train_loss);
}

#[test]
fn workers_change_modeled_time_not_semantics() {
    if runtime().is_none() { return }
    let rt = runtime().unwrap();
    let mut cfg = small_cfg();
    cfg.strategy = StrategyConfig::Baseline;
    cfg.workers = 1;
    let w1 = Trainer::new(&rt, cfg.clone()).unwrap().run().unwrap();
    cfg.workers = 8;
    let w8 = Trainer::new(&rt, cfg).unwrap().run().unwrap();
    // modeled time shrinks with workers; trained sample count unchanged
    assert!(w8.total_modeled_time < w1.total_modeled_time);
    assert_eq!(
        w1.records[0].trained_samples,
        w8.records[0].trained_samples
    );
}
