//! Engine determinism: the pipelined step-execution engine must produce
//! *bitwise-identical* results to a serial reference implementing the
//! pre-engine trainer loops verbatim — for all four execution modes
//! (plain train, plain train with weights, Selective-Backprop,
//! hidden-stat refresh, eval).
//!
//! The reference loops below are byte-for-byte transcriptions of the old
//! `Trainer::{execute_plain, execute_sb, refresh_stats, evaluate}` bodies
//! against a deterministic host-only mock backend, so the comparison
//! needs no PJRT artifacts and runs everywhere.  A final runtime-guarded
//! test repeats the check end-to-end through the real executor.

use kakurenbo::data::batch::BatchAssembler;
use kakurenbo::data::synth::{gauss_mixture, GaussMixtureCfg};
use kakurenbo::data::Dataset;
use kakurenbo::engine::{execute_plan, Engine, EvalSink, RefreshSink, StepBackend, StepMode};
use kakurenbo::runtime::BatchStats;
use kakurenbo::state::SampleState;
use kakurenbo::strategies::sb::SbSelector;
use kakurenbo::strategies::BatchMode;
use kakurenbo::util::rng::Rng;

const B: usize = 8;
const N: usize = 83; // ragged tail: 83 = 10*8 + 3

/// Deterministic, order-sensitive backend: a scalar parameter folds in
/// every training slot sequentially (f32 adds do not commute), and every
/// forward result depends on the parameter — so any reordering, skipped
/// step, or corrupted buffer in the pipeline changes downstream bits.
struct MockBackend {
    param: f32,
}

impl MockBackend {
    fn new() -> Self {
        MockBackend { param: 1.0 }
    }

    fn stats(&self, x: &[f32], y: &[i32], b: usize) -> BatchStats {
        let dim = x.len() / b;
        let mut s = BatchStats::default();
        for slot in 0..b {
            let xs: f32 = x[slot * dim..(slot + 1) * dim].iter().sum();
            let l = (xs * self.param).abs() + y[slot] as f32 * 0.125;
            s.loss.push(l);
            s.correct.push(if l < 1.5 { 1.0 } else { 0.0 });
            s.conf.push(1.0 / (1.0 + l));
        }
        s
    }
}

impl StepBackend for MockBackend {
    fn train_step(
        &mut self,
        x: &[f32],
        y: &[i32],
        sw: &[f32],
        lr: f32,
    ) -> anyhow::Result<BatchStats> {
        let b = sw.len();
        let stats = self.stats(x, y, b);
        for (slot, &w) in sw.iter().enumerate() {
            self.param += stats.loss[slot] * w * lr * 1e-3;
        }
        Ok(stats)
    }

    fn fwd_stats(&mut self, x: &[f32], y: &[i32]) -> anyhow::Result<BatchStats> {
        let b = y.len();
        Ok(self.stats(x, y, b))
    }
}

fn dataset() -> Dataset {
    gauss_mixture(
        &GaussMixtureCfg { n_train: N, n_val: 32, dim: 5, classes: 4, ..Default::default() },
        11,
    )
    .train
}

fn order() -> Vec<u32> {
    let mut rng = Rng::new(3);
    kakurenbo::sampler::epoch_permutation(N, &mut rng)
}

/// All recorded f32 state as bit patterns (bitwise comparison).
fn state_bits(s: &SampleState) -> (Vec<u32>, Vec<bool>, Vec<u32>, Vec<u32>) {
    (
        s.loss.iter().map(|l| l.to_bits()).collect(),
        s.correct.clone(),
        s.conf.iter().map(|c| c.to_bits()).collect(),
        s.last_update_epoch.clone(),
    )
}

// ---------------------------------------------------------------------------
// Serial references: verbatim transcriptions of the pre-engine trainer loops
// ---------------------------------------------------------------------------

/// Old `Trainer::execute_plain` (single worker, so no sharding branch).
fn ref_plain(
    backend: &mut MockBackend,
    data: &Dataset,
    order: &[u32],
    weights: Option<&[f32]>,
    lr: f32,
    epoch: u32,
    state: &mut SampleState,
) -> f64 {
    let mut asm = BatchAssembler::new(data, B);
    let mut loss_sum = 0.0f64;
    let mut loss_n = 0usize;
    for (ci, chunk) in order.chunks(B).enumerate() {
        let w: Option<&[f32]> = weights.map(|ws| &ws[ci * B..ci * B + chunk.len()]);
        asm.fill(data, chunk, w);
        let stats = backend.train_step(&asm.x, &asm.y, &asm.sw, lr).unwrap();
        for (slot, &sample) in chunk.iter().enumerate() {
            state.record(
                sample as usize,
                stats.loss[slot],
                stats.correct[slot] > 0.5,
                stats.conf[slot],
                epoch,
            );
            loss_sum += stats.loss[slot] as f64;
            loss_n += 1;
        }
    }
    loss_sum / loss_n.max(1) as f64
}

/// Old `Trainer::execute_sb`.
#[allow(clippy::too_many_arguments)]
fn ref_sb(
    backend: &mut MockBackend,
    data: &Dataset,
    order: &[u32],
    lr: f32,
    epoch: u32,
    state: &mut SampleState,
    sb: &mut SbSelector,
    rng: &mut Rng,
) -> (f64, usize) {
    let mut asm = BatchAssembler::new(data, B);
    let mut queue: Vec<u32> = Vec::new();
    let mut loss_sum = 0.0f64;
    let mut loss_n = 0usize;
    let mut backprop = 0usize;
    for chunk in order.chunks(B) {
        asm.fill(data, chunk, None);
        let stats = backend.fwd_stats(&asm.x, &asm.y).unwrap();
        for (slot, &sample) in chunk.iter().enumerate() {
            state.record(
                sample as usize,
                stats.loss[slot],
                stats.correct[slot] > 0.5,
                stats.conf[slot],
                epoch,
            );
            loss_sum += stats.loss[slot] as f64;
            loss_n += 1;
            if sb.accept(stats.loss[slot], rng) {
                queue.push(sample);
            }
        }
        while queue.len() >= B {
            let batch: Vec<u32> = queue.drain(..B).collect();
            asm.fill(data, &batch, None);
            backend.train_step(&asm.x, &asm.y, &asm.sw, lr).unwrap();
            backprop += B;
        }
    }
    if !queue.is_empty() {
        let batch: Vec<u32> = queue.drain(..).collect();
        asm.fill(data, &batch, None);
        backend.train_step(&asm.x, &asm.y, &asm.sw, lr).unwrap();
        backprop += batch.len();
    }
    (loss_sum / loss_n.max(1) as f64, backprop)
}

/// Old `Trainer::refresh_stats`.
fn ref_refresh(
    backend: &mut MockBackend,
    data: &Dataset,
    indices: &[u32],
    epoch: u32,
    state: &mut SampleState,
) {
    let mut asm = BatchAssembler::new(data, B);
    for chunk in indices.chunks(B) {
        asm.fill(data, chunk, None);
        let stats = backend.fwd_stats(&asm.x, &asm.y).unwrap();
        for (slot, &sample) in chunk.iter().enumerate() {
            state.record(
                sample as usize,
                stats.loss[slot],
                stats.correct[slot] > 0.5,
                stats.conf[slot],
                epoch,
            );
        }
    }
}

/// Old `Trainer::evaluate`.
fn ref_eval(backend: &mut MockBackend, val: &Dataset) -> (f64, f64) {
    let mut asm = BatchAssembler::new(val, B);
    let mut correct = 0.0f64;
    let mut loss = 0.0f64;
    let mut n = 0usize;
    let all: Vec<u32> = (0..val.n as u32).collect();
    for chunk in all.chunks(B) {
        asm.fill(val, chunk, None);
        let stats = backend.fwd_stats(&asm.x, &asm.y).unwrap();
        for slot in 0..chunk.len() {
            correct += stats.correct[slot] as f64;
            loss += stats.loss[slot] as f64;
            n += 1;
        }
    }
    (correct / n.max(1) as f64, loss / n.max(1) as f64)
}

fn pipelined_engine(data: &Dataset) -> Engine {
    let mut eng = Engine::new(data, B);
    eng.overlap = true; // force the prefetch-thread path even on 1 core
    eng
}

// ---------------------------------------------------------------------------
// Bitwise equivalence, mode by mode
// ---------------------------------------------------------------------------

#[test]
fn plain_mode_bitwise_identical() {
    let d = dataset();
    let order = order();

    let mut ref_be = MockBackend::new();
    let mut ref_state = SampleState::new(N);
    let ref_loss = ref_plain(&mut ref_be, &d, &order, None, 0.05, 3, &mut ref_state);

    let mut be = MockBackend::new();
    let mut state = SampleState::new(N);
    let mut eng = pipelined_engine(&d);
    let mut sb = SbSelector::new(1.0, 64);
    let mut rng = Rng::new(5);
    let mut queue = Vec::new();
    let out = execute_plan(
        &mut eng,
        &mut be,
        &d,
        &order,
        None,
        BatchMode::Plain,
        0.05,
        3,
        &mut state,
        &mut sb,
        &mut rng,
        &mut queue,
    )
    .unwrap();

    assert_eq!(state_bits(&ref_state), state_bits(&state));
    assert_eq!(ref_loss.to_bits(), out.train_loss.to_bits());
    assert_eq!(out.trained_samples, N);
    assert_eq!(out.backprop_samples, N);
    assert_eq!(ref_be.param.to_bits(), be.param.to_bits());
}

#[test]
fn weighted_plain_mode_bitwise_identical() {
    let d = dataset();
    let order = order();
    let weights: Vec<f32> = (0..N).map(|i| 0.5 + (i % 7) as f32 * 0.25).collect();

    let mut ref_be = MockBackend::new();
    let mut ref_state = SampleState::new(N);
    let ref_loss =
        ref_plain(&mut ref_be, &d, &order, Some(&weights), 0.02, 1, &mut ref_state);

    let mut be = MockBackend::new();
    let mut state = SampleState::new(N);
    let mut eng = pipelined_engine(&d);
    let mut sb = SbSelector::new(1.0, 64);
    let mut rng = Rng::new(5);
    let mut queue = Vec::new();
    let out = execute_plan(
        &mut eng,
        &mut be,
        &d,
        &order,
        Some(&weights),
        BatchMode::Plain,
        0.02,
        1,
        &mut state,
        &mut sb,
        &mut rng,
        &mut queue,
    )
    .unwrap();

    assert_eq!(state_bits(&ref_state), state_bits(&state));
    assert_eq!(ref_loss.to_bits(), out.train_loss.to_bits());
    assert_eq!(ref_be.param.to_bits(), be.param.to_bits());
}

#[test]
fn sb_mode_bitwise_identical() {
    let d = dataset();
    let order = order();

    let mut ref_be = MockBackend::new();
    let mut ref_state = SampleState::new(N);
    let mut ref_sbsel = SbSelector::new(1.0, 64);
    let mut ref_rng = Rng::new(17);
    let (ref_loss, ref_backprop) = ref_sb(
        &mut ref_be,
        &d,
        &order,
        0.05,
        2,
        &mut ref_state,
        &mut ref_sbsel,
        &mut ref_rng,
    );
    assert!(ref_backprop > 0, "SB reference never backpropped — weak test");

    let mut be = MockBackend::new();
    let mut state = SampleState::new(N);
    let mut eng = pipelined_engine(&d);
    let mut sb = SbSelector::new(1.0, 64);
    let mut rng = Rng::new(17);
    let mut queue = Vec::new();
    let out = execute_plan(
        &mut eng,
        &mut be,
        &d,
        &order,
        None,
        BatchMode::SelectiveBackprop { beta: 1.0 },
        0.05,
        2,
        &mut state,
        &mut sb,
        &mut rng,
        &mut queue,
    )
    .unwrap();

    assert_eq!(state_bits(&ref_state), state_bits(&state));
    assert_eq!(ref_loss.to_bits(), out.train_loss.to_bits());
    assert_eq!(ref_backprop, out.backprop_samples);
    assert_eq!(out.trained_samples, N);
    assert_eq!(ref_be.param.to_bits(), be.param.to_bits());
    assert!(queue.is_empty(), "finish() must flush the accept queue");
    // the RNG streams must have advanced identically
    assert_eq!(ref_rng.next_u64(), rng.next_u64());
}

#[test]
fn refresh_mode_bitwise_identical() {
    let d = dataset();
    let hidden: Vec<u32> = (0..N as u32).filter(|i| i % 3 == 0).collect();

    let mut ref_be = MockBackend::new();
    let mut ref_state = SampleState::new(N);
    ref_refresh(&mut ref_be, &d, &hidden, 4, &mut ref_state);

    let mut be = MockBackend::new();
    let mut state = SampleState::new(N);
    let mut eng = pipelined_engine(&d);
    let mut sink = RefreshSink::new(&mut state, 4);
    eng.run(&mut be, &d, &hidden, None, StepMode::Forward, &mut sink)
        .unwrap();

    assert_eq!(state_bits(&ref_state), state_bits(&state));
}

#[test]
fn eval_mode_bitwise_identical() {
    let tv = gauss_mixture(
        &GaussMixtureCfg { n_train: 16, n_val: 45, dim: 5, classes: 4, ..Default::default() },
        11,
    );

    let mut ref_be = MockBackend::new();
    let (ref_acc, ref_loss) = ref_eval(&mut ref_be, &tv.val);

    let mut be = MockBackend::new();
    let mut eng = pipelined_engine(&tv.val);
    let idx: Vec<u32> = (0..tv.val.n as u32).collect();
    let mut sink = EvalSink::default();
    eng.run(&mut be, &tv.val, &idx, None, StepMode::Forward, &mut sink)
        .unwrap();
    let (acc, loss) = sink.result();

    assert_eq!(ref_acc.to_bits(), acc.to_bits());
    assert_eq!(ref_loss.to_bits(), loss.to_bits());
}

/// Multi-epoch chain: state and parameter histories stay bit-identical
/// when every epoch runs through the pipelined engine vs. the reference.
#[test]
fn multi_epoch_chain_stays_identical() {
    let d = dataset();

    let mut ref_be = MockBackend::new();
    let mut ref_state = SampleState::new(N);
    let mut be = MockBackend::new();
    let mut state = SampleState::new(N);
    let mut eng = pipelined_engine(&d);
    let mut sb = SbSelector::new(1.0, 64);
    let mut rng = Rng::new(5);
    let mut queue = Vec::new();

    for epoch in 0..4u32 {
        let mut order_rng = Rng::new(100 + epoch as u64);
        let order = kakurenbo::sampler::epoch_permutation(N, &mut order_rng);
        let lr = 0.05 / (1.0 + epoch as f32);
        ref_plain(&mut ref_be, &d, &order, None, lr, epoch, &mut ref_state);
        execute_plan(
            &mut eng,
            &mut be,
            &d,
            &order,
            None,
            BatchMode::Plain,
            lr,
            epoch,
            &mut state,
            &mut sb,
            &mut rng,
            &mut queue,
        )
        .unwrap();
        assert_eq!(
            ref_be.param.to_bits(),
            be.param.to_bits(),
            "diverged at epoch {epoch}"
        );
    }
    assert_eq!(state_bits(&ref_state), state_bits(&state));
}

// ---------------------------------------------------------------------------
// End-to-end through the real executor (skipped when artifacts are absent)
// ---------------------------------------------------------------------------

mod end_to_end {
    use kakurenbo::config::{presets, DatasetConfig, StrategyConfig};
    use kakurenbo::coordinator::Trainer;
    use kakurenbo::metrics::RunResult;
    use kakurenbo::runtime::{default_artifacts_dir, XlaRuntime};

    fn runtime() -> Option<XlaRuntime> {
        XlaRuntime::new(&default_artifacts_dir()).ok()
    }

    fn run(rt: &XlaRuntime, strategy: StrategyConfig, overlap: bool) -> RunResult {
        let mut cfg = presets::by_name("cifar100_wrn").unwrap();
        cfg.epochs = 4;
        if let DatasetConfig::GaussMixture(ref mut c) = cfg.dataset {
            c.n_train = 512;
            c.n_val = 128;
        }
        cfg.eval_every = 2;
        cfg.strategy = strategy;
        let mut t = Trainer::new(rt, cfg).unwrap();
        t.engine.overlap = overlap;
        t.run().unwrap()
    }

    /// The pipelined engine must not change a single bit of any recorded
    /// epoch stat relative to serial execution, for every batch mode the
    /// strategies emit.
    #[test]
    fn trainer_pipelined_matches_serial() {
        let Some(rt) = runtime() else { return };
        for strategy in [
            StrategyConfig::Baseline,
            StrategyConfig::kakurenbo(0.3),
            StrategyConfig::SelectiveBackprop { beta: 1.0 },
            StrategyConfig::Iswr,
        ] {
            let serial = run(&rt, strategy.clone(), false);
            let piped = run(&rt, strategy.clone(), true);
            assert_eq!(serial.records.len(), piped.records.len());
            for (s, p) in serial.records.iter().zip(&piped.records) {
                let name = strategy.name();
                let e = s.epoch;
                assert_eq!(s.train_loss.to_bits(), p.train_loss.to_bits(), "{name} e{e}");
                assert_eq!(s.val_acc.to_bits(), p.val_acc.to_bits(), "{name} e{e}");
                assert_eq!(s.val_loss.to_bits(), p.val_loss.to_bits(), "{name} e{e}");
                assert_eq!(s.hidden, p.hidden, "{name} e{e}");
                assert_eq!(s.moved_back, p.moved_back, "{name} e{e}");
                assert_eq!(s.trained_samples, p.trained_samples, "{name} e{e}");
                assert_eq!(s.backprop_samples, p.backprop_samples, "{name} e{e}");
                assert_eq!(s.lr.to_bits(), p.lr.to_bits(), "{name} e{e}");
            }
        }
    }
}
