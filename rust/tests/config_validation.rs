//! CLI-level config validation: the `--dp` and `--serve` knobs must be
//! rejected with a clear error for configurations the schedule or the
//! inference server cannot honor, through the same parse → override →
//! validate pipeline the launcher runs (no runtime or artifacts
//! required).

use kakurenbo::cli::Args;
use kakurenbo::config::{presets, DpMode, ExperimentConfig, StrategyConfig};

/// The launcher's flag pipeline (main.rs `build_config`) distilled: parse
/// argv, apply the generic overrides, validate.
fn build_from_argv(argv: &[&str]) -> anyhow::Result<ExperimentConfig> {
    let args = Args::parse(argv.iter().map(|s| s.to_string()))?;
    let mut cfg = presets::by_name(args.flag_or("preset", "imagenet_resnet50"))?;
    if let Some(strategy) = args.flag("strategy") {
        cfg.strategy = match strategy {
            "baseline" => StrategyConfig::Baseline,
            "kakurenbo" => StrategyConfig::kakurenbo(0.3),
            "iswr" => StrategyConfig::Iswr,
            "sb" => StrategyConfig::SelectiveBackprop { beta: 1.0 },
            "infobatch" => StrategyConfig::InfoBatch { r: 0.3 },
            "gradmatch" => StrategyConfig::GradMatch { fraction: 0.3, every_r: 3 },
            "pfb" => StrategyConfig::Pfb { fraction: 0.3, refresh_every: 3 },
            other => anyhow::bail!("unknown strategy {other}"),
        };
    }
    for key in [
        "epochs",
        "seed",
        "workers",
        "dp",
        "serve",
        "serve-threads",
        "pfb-fraction",
        "pfb-refresh-every",
    ] {
        if let Some(v) = args.flag(key) {
            cfg.apply_override(key, v)?;
        }
    }
    cfg.validate()?;
    Ok(cfg)
}

#[test]
fn dp_average_with_single_worker_rejected_with_clear_error() {
    let err = build_from_argv(&["train", "--workers", "1", "--dp", "average"])
        .unwrap_err()
        .to_string();
    assert!(err.contains("--dp average"), "unhelpful error: {err}");
    assert!(err.contains("--workers > 1"), "unhelpful error: {err}");
}

#[test]
fn dp_average_with_weighted_or_sb_strategy_rejected_with_clear_error() {
    for strategy in ["iswr", "infobatch", "gradmatch", "sb"] {
        let err = build_from_argv(&[
            "train", "--workers", "4", "--dp", "average", "--strategy", strategy,
        ])
        .unwrap_err()
        .to_string();
        assert!(err.contains("--dp average"), "{strategy}: {err}");
        assert!(err.contains("single-stream"), "{strategy}: {err}");
    }
}

#[test]
fn dp_average_accepted_for_plain_strategies_with_workers() {
    for strategy in ["baseline", "kakurenbo"] {
        let cfg = build_from_argv(&[
            "train", "--workers", "4", "--dp", "average", "--strategy", strategy,
        ])
        .unwrap();
        assert_eq!(cfg.dp, DpMode::Average);
        assert_eq!(cfg.workers, 4);
    }
}

#[test]
fn unknown_dp_value_rejected_at_parse() {
    let err = build_from_argv(&["train", "--workers", "2", "--dp", "turbo"])
        .unwrap_err()
        .to_string();
    assert!(err.contains("--dp"), "{err}");
    assert!(err.contains("serial-equivalent") && err.contains("average"), "{err}");
}

#[test]
fn default_dp_is_serial_equivalent() {
    let cfg = build_from_argv(&["train", "--workers", "4"]).unwrap();
    assert_eq!(cfg.dp, DpMode::SerialEquivalent);
}

#[test]
fn serve_defaults_off_and_accepts_a_socket_address() {
    let cfg = build_from_argv(&["train"]).unwrap();
    assert_eq!(cfg.serve, None);
    assert_eq!(cfg.serve_threads, 2);
    // port 0 is explicitly supported (the OS picks a free port)
    let cfg = build_from_argv(&["train", "--serve", "127.0.0.1:0"]).unwrap();
    assert_eq!(cfg.serve.as_deref(), Some("127.0.0.1:0"));
    let cfg =
        build_from_argv(&["train", "--serve", "0.0.0.0:8080", "--serve-threads", "8"]).unwrap();
    assert_eq!(cfg.serve.as_deref(), Some("0.0.0.0:8080"));
    assert_eq!(cfg.serve_threads, 8);
}

#[test]
fn serve_bad_addresses_rejected_with_clear_error() {
    for addr in ["not-an-address", "8080", "127.0.0.1"] {
        let err = build_from_argv(&["train", "--serve", addr]).unwrap_err().to_string();
        assert!(err.contains("--serve"), "{addr}: {err}");
        assert!(err.contains("host:port"), "unhelpful error for {addr}: {err}");
    }
}

#[test]
fn pfb_refresh_every_zero_rejected_with_clear_error() {
    let err = build_from_argv(&["train", "--strategy", "pfb", "--pfb-refresh-every", "0"])
        .unwrap_err()
        .to_string();
    assert!(err.contains("--pfb-refresh-every 0"), "{err}");
    assert!(err.contains("at least every epoch"), "unhelpful error: {err}");
}

#[test]
fn pfb_flags_validate_range_and_strategy_scope() {
    // in-range override lands in the config
    let cfg = build_from_argv(&[
        "train", "--strategy", "pfb", "--pfb-fraction", "0.4", "--pfb-refresh-every", "5",
    ])
    .unwrap();
    match cfg.strategy {
        StrategyConfig::Pfb { fraction, refresh_every } => {
            assert_eq!(fraction, 0.4);
            assert_eq!(refresh_every, 5);
        }
        other => panic!("unexpected strategy {other:?}"),
    }
    // pruning the whole dataset is rejected, with the flag named
    let err = build_from_argv(&["train", "--strategy", "pfb", "--pfb-fraction", "1.0"])
        .unwrap_err()
        .to_string();
    assert!(err.contains("--pfb-fraction"), "{err}");
    // pfb flags refuse to apply to other strategies
    let err = build_from_argv(&["train", "--strategy", "baseline", "--pfb-fraction", "0.2"])
        .unwrap_err()
        .to_string();
    assert!(err.contains("--strategy pfb"), "{err}");
}

#[test]
fn serve_threads_zero_rejected_with_clear_error() {
    let err = build_from_argv(&["train", "--serve", "127.0.0.1:0", "--serve-threads", "0"])
        .unwrap_err()
        .to_string();
    assert!(err.contains("--serve-threads 0"), "{err}");
    assert!(err.contains("at least one worker"), "{err}");
}
