//! Integration: the PJRT runtime layer against the AOT artifacts.
//! Requires `make artifacts` (skips cleanly when artifacts are absent so
//! cargo test works in a fresh checkout).

use kakurenbo::runtime::{default_artifacts_dir, ModelExecutor, XlaRuntime};

fn runtime() -> Option<XlaRuntime> {
    XlaRuntime::new(&default_artifacts_dir()).ok()
}

fn batch_inputs(exec: &ModelExecutor, seed: u64) -> (Vec<f32>, Vec<i32>, Vec<f32>) {
    let mut rng = kakurenbo::util::rng::Rng::new(seed);
    let b = exec.meta.batch;
    let x: Vec<f32> = (0..b * exec.meta.sample_dim()).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let y: Vec<i32> = (0..b * exec.meta.label_len())
        .map(|_| rng.below(exec.meta.classes) as i32)
        .collect();
    let sw = vec![1.0f32; b];
    (x, y, sw)
}

#[test]
fn train_step_zero_lr_preserves_params() {
    let Some(rt) = runtime() else { return };
    let mut exec = ModelExecutor::new(&rt, "mlp_c10_b64", 7).unwrap();
    let before = exec.export_named_params().unwrap();
    let (x, y, sw) = batch_inputs(&exec, 1);
    // lr = 0: momentum update runs but w' = w - 0*v' = w
    exec.train_step(&x, &y, &sw, 0.0).unwrap();
    let after = exec.export_named_params().unwrap();
    for ((n1, p1), (n2, p2)) in before.iter().zip(&after) {
        assert_eq!(n1, n2);
        for (a, b) in p1.iter().zip(p2) {
            assert!((a - b).abs() < 1e-7, "{n1} changed under lr=0");
        }
    }
}

#[test]
fn train_step_zero_weights_preserve_params() {
    let Some(rt) = runtime() else { return };
    let mut exec = ModelExecutor::new(&rt, "mlp_c10_b64", 7).unwrap();
    let before = exec.export_named_params().unwrap();
    let (x, y, _) = batch_inputs(&exec, 2);
    let sw = vec![0.0f32; exec.meta.batch];
    exec.train_step(&x, &y, &sw, 0.5).unwrap();
    let after = exec.export_named_params().unwrap();
    for ((n1, p1), (_, p2)) in before.iter().zip(&after) {
        for (a, b) in p1.iter().zip(p2) {
            assert!((a - b).abs() < 1e-6, "{n1} changed under sw=0");
        }
    }
}

#[test]
fn fwd_stats_matches_train_step_stats() {
    // the stats returned by train_step are computed on the pre-update
    // params, so a fwd_stats call *before* the step must agree.
    let Some(rt) = runtime() else { return };
    let mut exec = ModelExecutor::new(&rt, "cnn_c32_b64", 3).unwrap();
    let (x, y, sw) = batch_inputs(&exec, 3);
    let fwd = exec.fwd_stats(&x, &y).unwrap();
    let step = exec.train_step(&x, &y, &sw, 0.05).unwrap();
    for i in 0..exec.meta.batch {
        assert!((fwd.loss[i] - step.loss[i]).abs() < 1e-4, "loss[{i}]");
        assert_eq!(fwd.correct[i], step.correct[i], "correct[{i}]");
        assert!((fwd.conf[i] - step.conf[i]).abs() < 1e-4, "conf[{i}]");
    }
}

#[test]
fn stats_are_well_formed() {
    let Some(rt) = runtime() else { return };
    let exec = ModelExecutor::new(&rt, "mlp_c100_b64", 11).unwrap();
    let (x, y, _) = batch_inputs(&exec, 4);
    let s = exec.fwd_stats(&x, &y).unwrap();
    assert_eq!(s.loss.len(), 64);
    for i in 0..64 {
        assert!(s.loss[i].is_finite() && s.loss[i] >= 0.0);
        assert!(s.correct[i] == 0.0 || s.correct[i] == 1.0);
        assert!(s.conf[i] > 0.0 && s.conf[i] <= 1.0 + 1e-5);
    }
}

#[test]
fn training_reduces_loss_on_learnable_batch() {
    let Some(rt) = runtime() else { return };
    let mut exec = ModelExecutor::new(&rt, "mlp_c10_b64", 5).unwrap();
    let (x, y, sw) = batch_inputs(&exec, 5);
    let first = exec.fwd_stats(&x, &y).unwrap();
    for _ in 0..60 {
        exec.train_step(&x, &y, &sw, 0.05).unwrap();
    }
    let last = exec.fwd_stats(&x, &y).unwrap();
    let m0: f32 = first.loss.iter().sum::<f32>() / 64.0;
    let m1: f32 = last.loss.iter().sum::<f32>() / 64.0;
    assert!(m1 < m0 * 0.3, "memorization failed: {m0} -> {m1}");
}

#[test]
fn fwd_embed_shapes_and_probs() {
    let Some(rt) = runtime() else { return };
    let exec = ModelExecutor::new(&rt, "cnn_c32_b64", 9).unwrap();
    let (x, y, _) = batch_inputs(&exec, 6);
    let e = exec.fwd_embed(&x, &y).unwrap();
    assert_eq!(e.emb.len(), 64 * exec.meta.embed_dim);
    assert_eq!(e.probs.len(), 64 * exec.meta.classes);
    for row in e.probs.chunks(exec.meta.classes) {
        let s: f32 = row.iter().sum();
        assert!((s - 1.0).abs() < 1e-3, "probs row sums to {s}");
        assert!(row.iter().all(|&p| (0.0..=1.0 + 1e-5).contains(&p)));
    }
}

#[test]
fn reset_params_is_deterministic() {
    let Some(rt) = runtime() else { return };
    let mut exec = ModelExecutor::new(&rt, "mlp_c10_b64", 42).unwrap();
    let a = exec.export_named_params().unwrap();
    let (x, y, sw) = batch_inputs(&exec, 7);
    exec.train_step(&x, &y, &sw, 0.1).unwrap();
    exec.reset_params(42).unwrap();
    let b = exec.export_named_params().unwrap();
    assert_eq!(a.len(), b.len());
    for ((_, pa), (_, pb)) in a.iter().zip(&b) {
        assert_eq!(pa, pb);
    }
    exec.reset_params(43).unwrap();
    let c = exec.export_named_params().unwrap();
    assert!(a.iter().zip(&c).any(|((_, pa), (_, pc))| pa != pc));
}

#[test]
fn import_named_params_matches_by_name_and_shape() {
    let Some(rt) = runtime() else { return };
    let src = ModelExecutor::new(&rt, "mlp_c64_b64", 1).unwrap();
    let mut dst = ModelExecutor::new(&rt, "mlp_c10_b64", 2).unwrap();
    let trunk = src.export_named_params().unwrap();
    let imported = dst.import_named_params(&trunk).unwrap();
    // fc1/fc2 (w+b) match; the c64 vs c10 heads must NOT transfer
    assert_eq!(imported, 4, "expected exactly the 4 trunk leaves");
    let dst_params = dst.export_named_params().unwrap();
    let src_fc1 = &trunk.iter().find(|(n, _)| n == "fc1/w").unwrap().1;
    let dst_fc1 = &dst_params.iter().find(|(n, _)| n == "fc1/w").unwrap().1;
    assert_eq!(src_fc1, dst_fc1);
}

#[test]
fn segnet_variant_runs() {
    let Some(rt) = runtime() else { return };
    let mut exec = ModelExecutor::new(&rt, "segnet_b32", 3).unwrap();
    assert_eq!(exec.meta.label_len(), 16 * 16);
    let (x, y, sw) = batch_inputs(&exec, 8);
    let s = exec.train_step(&x, &y, &sw, 0.01).unwrap();
    assert_eq!(s.loss.len(), 32);
    assert!(s.loss.iter().all(|l| l.is_finite()));
    // segnet has no embed artifact
    assert!(exec.fwd_embed(&x, &y).is_err());
}
