//! The exact-resume contract: a run interrupted at a checkpoint and
//! resumed with `--resume` must replay the uninterrupted run's tail **bit
//! for bit** — same loss curves, same hidden sets, same final parameters.
//!
//! This holds because a checkpoint now captures *everything* the next
//! epoch's planning and training read: model parameters + SGD momentum
//! (`runtime/checkpoint.rs`), and the coordinator-side per-sample stats,
//! RNG stream, and schedule offset (`coordinator/resume.rs`).
//!
//! All tests are skipped (not failed) when the PJRT artifacts are absent.

use kakurenbo::config::{presets, DatasetConfig, StrategyConfig};
use kakurenbo::coordinator::Trainer;
use kakurenbo::runtime::{default_artifacts_dir, XlaRuntime};

fn runtime() -> Option<XlaRuntime> {
    XlaRuntime::new(&default_artifacts_dir()).ok()
}

fn small_cfg() -> kakurenbo::config::ExperimentConfig {
    let mut cfg = presets::by_name("cifar100_wrn").unwrap();
    cfg.epochs = 6;
    if let DatasetConfig::GaussMixture(ref mut c) = cfg.dataset {
        c.n_train = 512;
        c.n_val = 128;
    }
    cfg.eval_every = 1;
    cfg
}

fn tmp_dir(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("kakurenbo_resume_{name}_{}", std::process::id()))
}

fn assert_records_bitwise_eq(
    a: &[kakurenbo::metrics::EpochRecord],
    b: &[kakurenbo::metrics::EpochRecord],
) {
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.epoch, y.epoch);
        assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits(), "epoch {}", x.epoch);
        assert_eq!(x.val_acc.to_bits(), y.val_acc.to_bits(), "epoch {}", x.epoch);
        assert_eq!(x.val_loss.to_bits(), y.val_loss.to_bits(), "epoch {}", x.epoch);
        assert_eq!(x.lr.to_bits(), y.lr.to_bits(), "epoch {}", x.epoch);
        assert_eq!(x.base_lr.to_bits(), y.base_lr.to_bits(), "epoch {}", x.epoch);
        assert_eq!(x.hidden, y.hidden, "epoch {}", x.epoch);
        assert_eq!(x.hidden_again, y.hidden_again, "epoch {}", x.epoch);
        assert_eq!(x.max_hidden, y.max_hidden, "epoch {}", x.epoch);
        assert_eq!(x.moved_back, y.moved_back, "epoch {}", x.epoch);
        assert_eq!(x.trained_samples, y.trained_samples, "epoch {}", x.epoch);
        assert_eq!(x.backprop_samples, y.backprop_samples, "epoch {}", x.epoch);
    }
}

fn assert_params_bitwise_eq(a: &Trainer, b: &Trainer) {
    let pa = a.exec.export_named_params().unwrap();
    let pb = b.exec.export_named_params().unwrap();
    assert_eq!(pa.len(), pb.len());
    for ((na, da), (nb, db)) in pa.iter().zip(&pb) {
        assert_eq!(na, nb);
        let ba: Vec<u32> = da.iter().map(|v| v.to_bits()).collect();
        let bb: Vec<u32> = db.iter().map(|v| v.to_bits()).collect();
        assert_eq!(ba, bb, "param {na} differs");
    }
}

/// Train k epochs with `checkpoint_every`, resume with `--resume`, and
/// the resumed run's records are bitwise identical to the uninterrupted
/// run's tail (KAKURENBO: the hiding selector, RNG shuffles, and LR
/// compensation all replay exactly).
#[test]
fn resumed_kakurenbo_run_matches_uninterrupted_tail() {
    let Some(rt) = runtime() else { return };
    let dir = tmp_dir("kaku");
    std::fs::remove_dir_all(&dir).ok();

    let mut cfg = small_cfg();
    cfg.strategy = StrategyConfig::kakurenbo(0.3);
    cfg.checkpoint_every = 2;
    cfg.checkpoint_dir = Some(dir.clone());

    // the uninterrupted reference run (no checkpointing, same seed)
    let mut ref_cfg = cfg.clone();
    ref_cfg.checkpoint_every = 0;
    ref_cfg.checkpoint_dir = None;
    let mut full = Trainer::new(&rt, ref_cfg).unwrap();
    let full_result = full.run().unwrap();

    // the "interrupted" run: epochs 0..3 only (the pipeline's checkpoint
    // phase writes at epoch 0 and 2), then the process "dies"
    {
        let mut t = Trainer::new(&rt, cfg.clone()).unwrap();
        for epoch in 0..3 {
            t.run_epoch(epoch).unwrap();
        }
    }

    // resume: picks up at epoch 3 from the epoch-2 checkpoint
    cfg.resume = true;
    let mut resumed = Trainer::new(&rt, cfg).unwrap();
    let resumed_result = resumed.run().unwrap();

    assert_eq!(resumed_result.records.first().unwrap().epoch, 3);
    assert_records_bitwise_eq(&resumed_result.records, &full_result.records[3..]);
    assert_params_bitwise_eq(&resumed, &full);
    std::fs::remove_dir_all(&dir).ok();
}

/// Same contract for the baseline strategy (pure-shuffle planning), and
/// through the async service lane: checkpoints written off the critical
/// path must resume just as exactly.
#[test]
fn resumed_baseline_run_matches_tail_via_service_lane() {
    let Some(rt) = runtime() else { return };
    let dir = tmp_dir("base_svc");
    std::fs::remove_dir_all(&dir).ok();

    let mut cfg = small_cfg();
    cfg.strategy = StrategyConfig::Baseline;
    cfg.checkpoint_every = 3;
    cfg.checkpoint_dir = Some(dir.clone());
    cfg.service_lane = true;

    let mut ref_cfg = cfg.clone();
    ref_cfg.checkpoint_every = 0;
    ref_cfg.checkpoint_dir = None;
    ref_cfg.service_lane = false;
    let mut full = Trainer::new(&rt, ref_cfg).unwrap();
    let full_result = full.run().unwrap();

    // interrupted after epoch 3 (checkpoints at epochs 0 and 3); the
    // trainer drops here, which drains the lane's in-flight writes
    {
        let mut t = Trainer::new(&rt, cfg.clone()).unwrap();
        for epoch in 0..4 {
            t.run_epoch(epoch).unwrap();
        }
    }

    cfg.resume = true;
    cfg.service_lane = false; // resume through the sync path
    let mut resumed = Trainer::new(&rt, cfg).unwrap();
    let resumed_result = resumed.run().unwrap();

    assert_eq!(resumed_result.records.first().unwrap().epoch, 4);
    assert_records_bitwise_eq(&resumed_result.records, &full_result.records[4..]);
    assert_params_bitwise_eq(&resumed, &full);
    std::fs::remove_dir_all(&dir).ok();
}

/// Legacy params-only checkpoints (no `vel` entries) still load — now
/// routed through the typed params-only snapshot tier
/// (`Snapshot::params_only` -> `StateExchange::import_snapshot`):
/// weights restore by name even from a shuffled legacy index, and
/// momentum keeps its current values.
#[test]
fn legacy_params_only_checkpoint_loads_via_typed_snapshot_path() {
    let Some(rt) = runtime() else { return };
    let dir = tmp_dir("legacy");
    std::fs::remove_dir_all(&dir).ok();

    use kakurenbo::engine::StateExchange;
    use kakurenbo::runtime::ModelExecutor;
    use kakurenbo::util::json::{parse_file, Json};

    let mut a = ModelExecutor::new(&rt, "mlp_c10_b64", 11).unwrap();
    let x = vec![0.3f32; 64 * 64];
    let y = vec![1i32; 64];
    let sw = vec![1.0f32; 64];
    // one step so both params and momentum move off their init
    a.train_step(&x, &y, &sw, 0.1).unwrap();
    kakurenbo::runtime::checkpoint::save(&a, &dir, 4).unwrap();

    // Strip the momentum generation down to a pre-full-state layout:
    // delete the v*.npy payloads, drop the "vel" index entries, and
    // shuffle the index order (legacy tools did not guarantee it).
    let path = dir.join("checkpoint.json");
    let mut m = parse_file(&path).unwrap();
    if let Json::Obj(obj) = &mut m {
        if let Some(Json::Arr(entries)) = obj.get_mut("params") {
            for e in entries.iter_mut() {
                if let Json::Obj(o) = e {
                    o.remove("vel");
                }
            }
            entries.reverse();
        }
    }
    std::fs::write(&path, m.to_pretty()).unwrap();
    for entry in std::fs::read_dir(&dir).unwrap() {
        let name = entry.unwrap().file_name().into_string().unwrap();
        if name.starts_with('v') && name.ends_with(".npy") {
            std::fs::remove_file(dir.join(&name)).unwrap();
        }
    }

    let mut b = ModelExecutor::new(&rt, "mlp_c10_b64", 999).unwrap();
    let momentum_before = StateExchange::export_momentum(&b).unwrap().unwrap();
    let epoch = kakurenbo::runtime::checkpoint::load(&mut b, &dir).unwrap();
    assert_eq!(epoch, 4);

    // parameters restored bit for bit despite the shuffled legacy index
    let pa = StateExchange::export_params(&a).unwrap();
    let pb = StateExchange::export_params(&b).unwrap();
    assert_eq!(pa.len(), pb.len());
    for (la, lb) in pa.iter().zip(&pb) {
        let ba: Vec<u32> = la.iter().map(|v| v.to_bits()).collect();
        let bb: Vec<u32> = lb.iter().map(|v| v.to_bits()).collect();
        assert_eq!(ba, bb);
    }
    // the params-only tier leaves momentum exactly as it was
    let momentum_after = StateExchange::export_momentum(&b).unwrap().unwrap();
    assert_eq!(momentum_before.len(), momentum_after.len());
    for (la, lb) in momentum_before.iter().zip(&momentum_after) {
        let ba: Vec<u32> = la.iter().map(|v| v.to_bits()).collect();
        let bb: Vec<u32> = lb.iter().map(|v| v.to_bits()).collect();
        assert_eq!(ba, bb);
    }
    std::fs::remove_dir_all(&dir).ok();
}
