//! The exact-resume contract: a run interrupted at a checkpoint and
//! resumed with `--resume` must replay the uninterrupted run's tail **bit
//! for bit** — same loss curves, same hidden sets, same final parameters.
//!
//! This holds because a checkpoint now captures *everything* the next
//! epoch's planning and training read: model parameters + SGD momentum
//! (`runtime/checkpoint.rs`), and the coordinator-side per-sample stats,
//! RNG stream, SB selector history, and schedule offset
//! (`coordinator/resume.rs`).
//!
//! Alongside the end-to-end resume contract, this file holds the
//! checkpoint store's durability tests — crash injection between leaf
//! writes and before the manifest flip, and sha256 corruption detection —
//! which run host-only against synthetic variants (no PJRT needed).
//! The end-to-end tests are skipped (not failed) when the PJRT artifacts
//! are absent.

use kakurenbo::config::{presets, DatasetConfig, StrategyConfig};
use kakurenbo::coordinator::Trainer;
use kakurenbo::engine::{SharedSnapshot, Snapshot};
use kakurenbo::runtime::artifact::{ParamMeta, VariantMeta};
use kakurenbo::runtime::checkpoint::{load_snapshot, save_snapshot};
use kakurenbo::runtime::{default_artifacts_dir, XlaRuntime};
use kakurenbo::util::artifact::{object_file, store_leaf, WritePool};
use kakurenbo::util::npy;

fn runtime() -> Option<XlaRuntime> {
    XlaRuntime::new(&default_artifacts_dir()).ok()
}

fn small_cfg() -> kakurenbo::config::ExperimentConfig {
    let mut cfg = presets::by_name("cifar100_wrn").unwrap();
    cfg.epochs = 6;
    if let DatasetConfig::GaussMixture(ref mut c) = cfg.dataset {
        c.n_train = 512;
        c.n_val = 128;
    }
    cfg.eval_every = 1;
    cfg
}

fn tmp_dir(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("kakurenbo_resume_{name}_{}", std::process::id()))
}

fn assert_records_bitwise_eq(
    a: &[kakurenbo::metrics::EpochRecord],
    b: &[kakurenbo::metrics::EpochRecord],
) {
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.epoch, y.epoch);
        assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits(), "epoch {}", x.epoch);
        assert_eq!(x.val_acc.to_bits(), y.val_acc.to_bits(), "epoch {}", x.epoch);
        assert_eq!(x.val_loss.to_bits(), y.val_loss.to_bits(), "epoch {}", x.epoch);
        assert_eq!(x.lr.to_bits(), y.lr.to_bits(), "epoch {}", x.epoch);
        assert_eq!(x.base_lr.to_bits(), y.base_lr.to_bits(), "epoch {}", x.epoch);
        assert_eq!(x.hidden, y.hidden, "epoch {}", x.epoch);
        assert_eq!(x.hidden_again, y.hidden_again, "epoch {}", x.epoch);
        assert_eq!(x.max_hidden, y.max_hidden, "epoch {}", x.epoch);
        assert_eq!(x.moved_back, y.moved_back, "epoch {}", x.epoch);
        assert_eq!(x.trained_samples, y.trained_samples, "epoch {}", x.epoch);
        assert_eq!(x.backprop_samples, y.backprop_samples, "epoch {}", x.epoch);
        assert_eq!(x.pruned_pre_forward, y.pruned_pre_forward, "epoch {}", x.epoch);
        assert_eq!(x.feature_cache_age, y.feature_cache_age, "epoch {}", x.epoch);
    }
}

fn assert_params_bitwise_eq(a: &Trainer, b: &Trainer) {
    let pa = a.exec.export_named_params().unwrap();
    let pb = b.exec.export_named_params().unwrap();
    assert_eq!(pa.len(), pb.len());
    for ((na, da), (nb, db)) in pa.iter().zip(&pb) {
        assert_eq!(na, nb);
        let ba: Vec<u32> = da.iter().map(|v| v.to_bits()).collect();
        let bb: Vec<u32> = db.iter().map(|v| v.to_bits()).collect();
        assert_eq!(ba, bb, "param {na} differs");
    }
}

/// Train k epochs with `checkpoint_every`, resume with `--resume`, and
/// the resumed run's records are bitwise identical to the uninterrupted
/// run's tail (KAKURENBO: the hiding selector, RNG shuffles, and LR
/// compensation all replay exactly).
#[test]
fn resumed_kakurenbo_run_matches_uninterrupted_tail() {
    let Some(rt) = runtime() else { return };
    let dir = tmp_dir("kaku");
    std::fs::remove_dir_all(&dir).ok();

    let mut cfg = small_cfg();
    cfg.strategy = StrategyConfig::kakurenbo(0.3);
    cfg.checkpoint_every = 2;
    cfg.checkpoint_dir = Some(dir.clone());

    // the uninterrupted reference run (no checkpointing, same seed)
    let mut ref_cfg = cfg.clone();
    ref_cfg.checkpoint_every = 0;
    ref_cfg.checkpoint_dir = None;
    let mut full = Trainer::new(&rt, ref_cfg).unwrap();
    let full_result = full.run().unwrap();

    // the "interrupted" run: epochs 0..3 only (the pipeline's checkpoint
    // phase writes at epoch 0 and 2), then the process "dies"
    {
        let mut t = Trainer::new(&rt, cfg.clone()).unwrap();
        for epoch in 0..3 {
            t.run_epoch(epoch).unwrap();
        }
    }

    // resume: picks up at epoch 3 from the epoch-2 checkpoint
    cfg.resume = true;
    let mut resumed = Trainer::new(&rt, cfg).unwrap();
    let resumed_result = resumed.run().unwrap();

    assert_eq!(resumed_result.records.first().unwrap().epoch, 3);
    assert_records_bitwise_eq(&resumed_result.records, &full_result.records[3..]);
    assert_params_bitwise_eq(&resumed, &full);
    std::fs::remove_dir_all(&dir).ok();
}

/// Same contract for the baseline strategy (pure-shuffle planning), and
/// through the async service lane: checkpoints written off the critical
/// path must resume just as exactly.
#[test]
fn resumed_baseline_run_matches_tail_via_service_lane() {
    let Some(rt) = runtime() else { return };
    let dir = tmp_dir("base_svc");
    std::fs::remove_dir_all(&dir).ok();

    let mut cfg = small_cfg();
    cfg.strategy = StrategyConfig::Baseline;
    cfg.checkpoint_every = 3;
    cfg.checkpoint_dir = Some(dir.clone());
    cfg.service_lane = true;

    let mut ref_cfg = cfg.clone();
    ref_cfg.checkpoint_every = 0;
    ref_cfg.checkpoint_dir = None;
    ref_cfg.service_lane = false;
    let mut full = Trainer::new(&rt, ref_cfg).unwrap();
    let full_result = full.run().unwrap();

    // interrupted after epoch 3 (checkpoints at epochs 0 and 3); the
    // trainer drops here, which drains the lane's in-flight writes
    {
        let mut t = Trainer::new(&rt, cfg.clone()).unwrap();
        for epoch in 0..4 {
            t.run_epoch(epoch).unwrap();
        }
    }

    cfg.resume = true;
    cfg.service_lane = false; // resume through the sync path
    let mut resumed = Trainer::new(&rt, cfg).unwrap();
    let resumed_result = resumed.run().unwrap();

    assert_eq!(resumed_result.records.first().unwrap().epoch, 4);
    assert_records_bitwise_eq(&resumed_result.records, &full_result.records[4..]);
    assert_params_bitwise_eq(&resumed, &full);
    std::fs::remove_dir_all(&dir).ok();
}

/// PFB's resume contract crosses the feature-cache lifetime: the run is
/// killed *between* cache refreshes, so the epoch the resumed run plans
/// first must score from embedding rows harvested epochs earlier — rows
/// that only exist if the checkpoint carried them (`state_pfb_feats`)
/// and `--resume` restored them bit for bit.  A resume that silently
/// re-harvested (or started cold) would shift the prune set and diverge
/// from the uninterrupted run's tail.
#[test]
fn resumed_pfb_run_restores_feature_cache_mid_lifetime() {
    let Some(rt) = runtime() else { return };
    let dir = tmp_dir("pfb");
    std::fs::remove_dir_all(&dir).ok();

    let mut cfg = small_cfg();
    cfg.strategy = StrategyConfig::Pfb { fraction: 0.25, refresh_every: 3 };
    cfg.checkpoint_every = 2;
    cfg.checkpoint_dir = Some(dir.clone());

    let mut ref_cfg = cfg.clone();
    ref_cfg.checkpoint_every = 0;
    ref_cfg.checkpoint_dir = None;
    let mut full = Trainer::new(&rt, ref_cfg).unwrap();
    let full_result = full.run().unwrap();

    // killed after epoch 2: the epoch-2 checkpoint carries the cache
    // harvested at epoch 0 (refresh_every=3 defers the next harvest to
    // epoch 3), so the kill lands mid-cache-lifetime
    {
        let mut t = Trainer::new(&rt, cfg.clone()).unwrap();
        for epoch in 0..3 {
            t.run_epoch(epoch).unwrap();
        }
        assert_eq!(t.feat_cache.age(2), 2, "kill point must sit between harvests");
    }

    // resume: epoch 3 plans from the *restored* epoch-0 embedding rows
    cfg.resume = true;
    let mut resumed = Trainer::new(&rt, cfg).unwrap();
    let resumed_result = resumed.run().unwrap();

    assert_eq!(resumed_result.records.first().unwrap().epoch, 3);
    assert_records_bitwise_eq(&resumed_result.records, &full_result.records[3..]);
    assert_params_bitwise_eq(&resumed, &full);
    std::fs::remove_dir_all(&dir).ok();
}

/// Legacy params-only checkpoints (no `vel` entries) still load — now
/// routed through the typed params-only snapshot tier
/// (`Snapshot::params_only` -> `StateExchange::import_snapshot`):
/// weights restore by name even from a shuffled legacy index, and
/// momentum keeps its current values.
#[test]
fn legacy_params_only_checkpoint_loads_via_typed_snapshot_path() {
    let Some(rt) = runtime() else { return };
    let dir = tmp_dir("legacy");
    std::fs::remove_dir_all(&dir).ok();

    use kakurenbo::engine::StateExchange;
    use kakurenbo::runtime::ModelExecutor;
    use kakurenbo::util::json::Json;

    let mut a = ModelExecutor::new(&rt, "mlp_c10_b64", 11).unwrap();
    let x = vec![0.3f32; 64 * 64];
    let y = vec![1i32; 64];
    let sw = vec![1.0f32; 64];
    // one step so both params and momentum move off their init
    a.train_step(&x, &y, &sw, 0.1).unwrap();

    // Hand-author the oldest on-disk layout: epoch-suffixed p*.npy
    // payloads plus a `{name, file}` index — no format tag, no digests,
    // no momentum — in shuffled order (legacy tools did not guarantee
    // it).  The loader must still route it through the params-only
    // snapshot tier.
    std::fs::create_dir_all(&dir).unwrap();
    let params = StateExchange::export_params(&a).unwrap();
    let mut entries = Vec::new();
    for (i, (leaf, data)) in a.meta.params.iter().zip(&params).enumerate() {
        let fname = format!("p{i:03}_{}.e4.npy", leaf.name.replace('/', "_"));
        npy::write_f32(&dir.join(&fname), data, &leaf.shape).unwrap();
        entries.push(kakurenbo::jobj![
            ("name", leaf.name.as_str()),
            ("file", fname.as_str()),
        ]);
    }
    entries.reverse();
    let manifest = kakurenbo::jobj![
        ("variant", a.meta.name.as_str()),
        ("epoch", 4usize),
        ("param_count", a.meta.param_count),
        ("params", Json::Arr(entries)),
    ];
    std::fs::write(dir.join("checkpoint.json"), manifest.to_pretty()).unwrap();

    let mut b = ModelExecutor::new(&rt, "mlp_c10_b64", 999).unwrap();
    let momentum_before = StateExchange::export_momentum(&b).unwrap().unwrap();
    let epoch = kakurenbo::runtime::checkpoint::load(&mut b, &dir).unwrap();
    assert_eq!(epoch, 4);

    // parameters restored bit for bit despite the shuffled legacy index
    let pa = StateExchange::export_params(&a).unwrap();
    let pb = StateExchange::export_params(&b).unwrap();
    assert_eq!(pa.len(), pb.len());
    for (la, lb) in pa.iter().zip(&pb) {
        let ba: Vec<u32> = la.iter().map(|v| v.to_bits()).collect();
        let bb: Vec<u32> = lb.iter().map(|v| v.to_bits()).collect();
        assert_eq!(ba, bb);
    }
    // the params-only tier leaves momentum exactly as it was
    let momentum_after = StateExchange::export_momentum(&b).unwrap().unwrap();
    assert_eq!(momentum_before.len(), momentum_after.len());
    for (la, lb) in momentum_before.iter().zip(&momentum_after) {
        let ba: Vec<u32> = la.iter().map(|v| v.to_bits()).collect();
        let bb: Vec<u32> = lb.iter().map(|v| v.to_bits()).collect();
        assert_eq!(ba, bb);
    }
    std::fs::remove_dir_all(&dir).ok();
}

// --- checkpoint store durability (host-only, synthetic variants) -----------

fn synth_meta(leaves: usize, numel: usize) -> VariantMeta {
    VariantMeta {
        name: "synthetic".into(),
        family: "test".into(),
        batch: 8,
        input_shape: vec![4],
        label_shape: vec![1],
        classes: 2,
        embed_dim: 0,
        param_count: leaves * numel,
        params: (0..leaves)
            .map(|i| ParamMeta {
                name: format!("block{i}/w"),
                shape: vec![numel],
                init_std: 0.1,
            })
            .collect(),
        artifacts: Default::default(),
    }
}

fn synth_snapshot(meta: &VariantMeta, seed: f32) -> SharedSnapshot {
    let params: Vec<Vec<f32>> = meta
        .params
        .iter()
        .enumerate()
        .map(|(i, p)| (0..p.numel()).map(|j| seed + i as f32 + j as f32 * 0.001).collect())
        .collect();
    let vels: Vec<Vec<f32>> =
        meta.params.iter().map(|p| vec![seed * 0.5; p.numel()]).collect();
    std::sync::Arc::new(Snapshot::full(params, Some(vels)))
}

fn assert_snapshot_bits_eq(a: &Snapshot, b: &Snapshot) {
    assert_eq!(a.params().len(), b.params().len());
    for (la, lb) in a.params().iter().zip(b.params()) {
        let ba: Vec<u32> = la.iter().map(|v| v.to_bits()).collect();
        let bb: Vec<u32> = lb.iter().map(|v| v.to_bits()).collect();
        assert_eq!(ba, bb);
    }
    let (ma, mb) = (a.momentum().unwrap(), b.momentum().unwrap());
    assert_eq!(ma.len(), mb.len());
    for (la, lb) in ma.iter().zip(mb) {
        let ba: Vec<u32> = la.iter().map(|v| v.to_bits()).collect();
        let bb: Vec<u32> = lb.iter().map(|v| v.to_bits()).collect();
        assert_eq!(ba, bb);
    }
}

/// A writer killed partway through a generation's leaf writes leaves
/// orphaned objects and a stray `.tmp` behind, but never a manifest that
/// references them: resume falls back to the previous generation
/// bit-exactly.
#[test]
fn crash_between_leaf_writes_falls_back_to_previous_generation() {
    let dir = tmp_dir("crash_leaves");
    std::fs::remove_dir_all(&dir).ok();
    let meta = synth_meta(4, 256);
    let gen_a = synth_snapshot(&meta, 1.0);
    let pool = WritePool::serial();
    save_snapshot(&meta, &gen_a, &dir, 3, &pool, true).unwrap();

    // the "crash": generation B got two leaves and half a third onto
    // disk before the process died — checkpoint.json still points at A
    let gen_b = synth_snapshot(&meta, 9.0);
    store_leaf(&dir, &npy::encode_f32(&gen_b.params()[0], &[256]).unwrap(), false).unwrap();
    store_leaf(&dir, &npy::encode_f32(&gen_b.params()[1], &[256]).unwrap(), false).unwrap();
    let stray = format!("{}.17.tmp", object_file(&"ab".repeat(32)));
    std::fs::write(dir.join(stray), b"torn half-write").unwrap();

    let (snap, epoch) = load_snapshot(&meta, &dir, true).unwrap();
    assert_eq!(epoch, 3);
    assert_snapshot_bits_eq(&snap, &gen_a);

    // and the *next* successful save sweeps the orphans
    save_snapshot(&meta, &gen_b, &dir, 4, &pool, true).unwrap();
    assert!(!dir
        .join(format!("{}.17.tmp", object_file(&"ab".repeat(32))))
        .exists());
    let (snap, epoch) = load_snapshot(&meta, &dir, true).unwrap();
    assert_eq!(epoch, 4);
    assert_snapshot_bits_eq(&snap, &gen_b);
    std::fs::remove_dir_all(&dir).ok();
}

/// A writer killed after every payload landed but before the manifest
/// flip: the store holds generation B's objects in full, yet the
/// checkpoint still *is* generation A — the atomic manifest rename is
/// the commit point.
#[test]
fn crash_before_manifest_flip_keeps_previous_generation() {
    let dir = tmp_dir("crash_flip");
    std::fs::remove_dir_all(&dir).ok();
    let meta = synth_meta(4, 256);
    let gen_a = synth_snapshot(&meta, 1.0);
    let pool = WritePool::serial();
    save_snapshot(&meta, &gen_a, &dir, 3, &pool, true).unwrap();

    // generation B's payloads all complete (params raw, momentum
    // compressed — exactly what save_snapshot would have staged), then
    // the process dies before writing checkpoint.json
    let gen_b = synth_snapshot(&meta, 9.0);
    for i in 0..4 {
        let p = npy::encode_f32(&gen_b.params()[i], &[256]).unwrap();
        store_leaf(&dir, &p, false).unwrap();
        let v = npy::encode_f32(&gen_b.momentum().unwrap()[i], &[256]).unwrap();
        store_leaf(&dir, &v, true).unwrap();
    }

    let (snap, epoch) = load_snapshot(&meta, &dir, true).unwrap();
    assert_eq!(epoch, 3);
    assert_snapshot_bits_eq(&snap, &gen_a);
    std::fs::remove_dir_all(&dir).ok();
}

/// A flipped byte in a stored leaf is caught by the manifest's sha256
/// digest as a named error — not a deserialization panic — and
/// `--checkpoint-verify off` skips the check.
#[test]
fn corrupted_leaf_is_a_named_sha256_mismatch() {
    let dir = tmp_dir("corrupt");
    std::fs::remove_dir_all(&dir).ok();
    let meta = synth_meta(4, 256);
    let snap = synth_snapshot(&meta, 2.0);
    let pool = WritePool::serial();
    save_snapshot(&meta, &snap, &dir, 5, &pool, true).unwrap();

    // flip one byte in the tail (f32 payload region) of the first
    // *param* leaf — params are stored raw, so the frame still decodes
    // and only the digest can tell
    let m = kakurenbo::util::json::parse_file(&dir.join("checkpoint.json")).unwrap();
    let digest = m.req("params").unwrap().as_arr().unwrap()[0]
        .req("digest")
        .unwrap()
        .as_str()
        .unwrap()
        .to_string();
    let path = dir.join(object_file(&digest));
    let mut bytes = std::fs::read(&path).unwrap();
    let last = bytes.len() - 1;
    bytes[last] ^= 0x40;
    std::fs::write(&path, &bytes).unwrap();

    let err = load_snapshot(&meta, &dir, true).unwrap_err().to_string();
    assert!(err.contains("sha256 mismatch"), "{err}");
    assert!(err.contains(&digest), "error must name the expected digest: {err}");
    assert!(err.contains("block0/w"), "error must name the leaf: {err}");

    // verify off: the corrupt (but structurally valid) leaf loads
    let (loaded, epoch) = load_snapshot(&meta, &dir, false).unwrap();
    assert_eq!(epoch, 5);
    assert_ne!(
        loaded.params()[0].last().unwrap().to_bits(),
        snap.params()[0].last().unwrap().to_bits(),
        "the flipped byte should have changed the decoded value"
    );
    std::fs::remove_dir_all(&dir).ok();
}
