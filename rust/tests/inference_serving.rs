//! The online inference fleet's end-to-end serving battery (ISSUE 8/9).
//!
//! Four contracts, layered like the other suites:
//!
//!   * **Fidelity** (mock stack, always runs): an answer served over
//!     HTTP/JSON is bitwise identical to calling the backend directly on
//!     the same snapshot — the JSON number formatter is shortest
//!     round-trip, so f32 stats survive the wire exactly.
//!   * **Atomicity** (mock stack, always runs): a hammer of concurrent
//!     queries across a stream of snapshot publications never observes
//!     torn state — every response's epoch is internally consistent with
//!     its digests / its stats, for ≥ 1000 queries.
//!   * **Equivalence** (mock stack, always runs): for random query sets
//!     and random batch/replica configurations, the coalescing
//!     multi-replica fleet answers bitwise identically to per-query
//!     single-lane serving, and every query gets exactly one reply —
//!     even when a chaos-killed lane forces mid-flight redispatch.
//!   * **Isolation** (PJRT, skipped without artifacts): training with
//!     `--serve` on produces records bitwise identical to off — including
//!     composed with `--service-lane on`, `--workers 4`,
//!     `--serve-replicas 2` and `--serve-batch 8` — and a faulting
//!     serving replica follows the run's `--fault-policy` (named abort
//!     under `fail`, count-and-degrade under `elastic`).

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use kakurenbo::config::{presets, DatasetConfig, FaultPolicy, StrategyConfig};
use kakurenbo::coordinator::{ServeRuntime, Trainer};
use kakurenbo::engine::serve::leaf_digests;
use kakurenbo::engine::testbed::MockBackend;
use kakurenbo::engine::{
    DataParallel, ServeAnswer, ServeBatching, ServeFleet, Snapshot, SnapshotHub, StateExchange,
    StepBackend,
};
use kakurenbo::runtime::{default_artifacts_dir, XlaRuntime};
use kakurenbo::serve::{http_request, InferenceServer};
use kakurenbo::util::json::{self, Json};
use kakurenbo::util::rng::Rng;

/// A full mock serving stack: hub + single serving replica + HTTP server.
fn mock_stack(threads: usize) -> (InferenceServer, Arc<SnapshotHub>, ServeFleet) {
    let hub = Arc::new(SnapshotHub::new());
    let fleet =
        ServeFleet::spawn_single(MockBackend::new().replica_builder().unwrap(), hub.clone())
            .unwrap();
    let srv = InferenceServer::start("127.0.0.1:0", threads, hub.clone(), fleet.client(), None)
        .unwrap();
    (srv, hub, fleet)
}

/// Direct (no HTTP, no lane) reference stats for `param` on (`x`, `y`).
fn direct_stats(param: f32, x: &[f32], y: &[i32]) -> kakurenbo::runtime::BatchStats {
    let mut be = MockBackend::new();
    be.import_params(&[vec![param]]).unwrap();
    be.fwd_stats(x, y).unwrap()
}

fn f32_bits(v: &Json, key: &str) -> Vec<u32> {
    v.get(key)
        .unwrap_or_else(|| panic!("response missing {key:?}: {v:?}"))
        .as_arr()
        .unwrap()
        .iter()
        .map(|n| (n.as_f64().unwrap() as f32).to_bits())
        .collect()
}

/// Fidelity: `/v1/stats` and `/v1/embed` responses carry the exact bits
/// the backend produced for the published snapshot — JSON transport is
/// lossless for f32.
#[test]
fn served_answers_are_bitwise_equal_to_direct_forward() {
    let (srv, hub, _fleet) = mock_stack(2);
    let param = 0.62584335_f32; // deliberately not a short decimal
    hub.publish(3, Arc::new(Snapshot::params_only(vec![vec![param]])));

    let x = [0.1234567_f32, 0.7654321, 0.33333334, 0.9999999];
    let y = [1_i32, 2];
    let want = direct_stats(param, &x, &y);
    let mut emb_be = MockBackend::new();
    emb_be.import_params(&[vec![param]]).unwrap();
    let want_emb = emb_be.fwd_embed(&x, &y).unwrap();

    let body = format!(
        r#"{{"x": [[{}, {}], [{}, {}]], "y": [1, 2]}}"#,
        x[0], x[1], x[2], x[3]
    );
    let (status, resp) = http_request(srv.addr(), "POST", "/v1/stats", Some(&body)).unwrap();
    assert_eq!(status, 200, "{resp}");
    let v = json::parse(&resp).unwrap();
    assert_eq!(v.get("epoch").unwrap().as_usize(), Some(3));
    let want_loss: Vec<u32> = want.loss.iter().map(|l| l.to_bits()).collect();
    let want_conf: Vec<u32> = want.conf.iter().map(|c| c.to_bits()).collect();
    assert_eq!(f32_bits(&v, "loss"), want_loss, "loss bits drifted over the wire");
    assert_eq!(f32_bits(&v, "conf"), want_conf, "conf bits drifted over the wire");

    let (status, resp) = http_request(srv.addr(), "POST", "/v1/embed", Some(&body)).unwrap();
    assert_eq!(status, 200, "{resp}");
    let v = json::parse(&resp).unwrap();
    let want_e: Vec<u32> = want_emb.emb.iter().map(|e| e.to_bits()).collect();
    let want_p: Vec<u32> = want_emb.probs.iter().map(|p| p.to_bits()).collect();
    assert_eq!(f32_bits(&v, "emb"), want_e, "emb bits drifted over the wire");
    assert_eq!(f32_bits(&v, "probs"), want_p, "probs bits drifted over the wire");

    // the lane counted the two forwards
    assert_eq!(hub.take_queries(), 2);
}

/// Atomicity: concurrent queriers racing a stream of publications.  Every
/// `/v1/snapshot` response's digests must be the published digests *of
/// its own epoch*, and every `/v1/stats` response's loss must be the
/// value *its* epoch's parameters produce — across ≥ 1000 queries and
/// dozens of swaps, no response may mix two publications.
#[test]
fn swap_hammer_never_observes_torn_state() {
    const EPOCHS: usize = 24;
    const QUERIERS: usize = 4;
    const MIN_PER_THREAD: usize = 260;

    let (srv, hub, _fleet) = mock_stack(QUERIERS);
    let param_at = |e: usize| (e as f32 + 1.0) * 0.25;
    let x = [0.3_f32, 0.6];
    let y = [1_i32];
    // per-epoch expectations, computed before any server traffic
    let expected: Vec<(Vec<String>, u32)> = (0..EPOCHS)
        .map(|e| {
            let digests =
                leaf_digests(&Snapshot::params_only(vec![vec![param_at(e)]]));
            let loss = direct_stats(param_at(e), &x, &y).loss[0].to_bits();
            (digests, loss)
        })
        .collect();

    hub.publish(0, Arc::new(Snapshot::params_only(vec![vec![param_at(0)]])));
    let done = Arc::new(AtomicBool::new(false));
    let total = Arc::new(AtomicUsize::new(0));
    let addr = srv.addr();
    let mut threads = Vec::new();
    for q in 0..QUERIERS {
        let done = done.clone();
        let total = total.clone();
        let expected = expected.clone();
        threads.push(std::thread::spawn(move || {
            let mut mine = 0usize;
            while !done.load(Ordering::Relaxed) || mine < MIN_PER_THREAD {
                if (mine + q) % 2 == 0 {
                    let (status, resp) =
                        http_request(addr, "GET", "/v1/snapshot", None).unwrap();
                    assert_eq!(status, 200, "{resp}");
                    let v = json::parse(&resp).unwrap();
                    let epoch = v.get("epoch").unwrap().as_usize().unwrap();
                    let digests: Vec<String> = v
                        .get("digests")
                        .unwrap()
                        .as_arr()
                        .unwrap()
                        .iter()
                        .map(|d| d.as_str().unwrap().to_string())
                        .collect();
                    assert_eq!(
                        digests, expected[epoch].0,
                        "epoch {epoch} paired with another epoch's digests"
                    );
                } else {
                    let (status, resp) = http_request(
                        addr,
                        "POST",
                        "/v1/stats",
                        Some(r#"{"x": [[0.3, 0.6]], "y": [1]}"#),
                    )
                    .unwrap();
                    assert_eq!(status, 200, "{resp}");
                    let v = json::parse(&resp).unwrap();
                    let epoch = v.get("epoch").unwrap().as_usize().unwrap();
                    let loss = f32_bits(&v, "loss");
                    assert_eq!(
                        loss[0], expected[epoch].1,
                        "epoch {epoch} answered with another epoch's parameters"
                    );
                }
                mine += 1;
            }
            total.fetch_add(mine, Ordering::Relaxed);
        }));
    }
    // publish the remaining epochs while the queriers hammer
    for e in 1..EPOCHS {
        hub.publish(e, Arc::new(Snapshot::params_only(vec![vec![param_at(e)]])));
        std::thread::sleep(std::time::Duration::from_millis(3));
    }
    done.store(true, Ordering::Relaxed);
    for t in threads {
        t.join().unwrap();
    }
    let total = total.load(Ordering::Relaxed);
    assert!(total >= 1000, "hammer too small to be meaningful: {total} queries");
    assert_eq!(hub.publishes(), EPOCHS);
    assert!(hub.take_queries() > 0);
}

/// One randomly generated forward query: row-major `x`, labels `y`,
/// endpoint selector, and the answer slot it must fill exactly once.
struct PropQuery {
    x: Vec<f32>,
    y: Vec<i32>,
    embed: bool,
}

fn assert_answers_bitwise_eq(got: &ServeAnswer, want: &ServeAnswer, ctx: &str) {
    assert_eq!(got.epoch, want.epoch, "{ctx}: epoch");
    let bits = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<u32>>();
    assert_eq!(bits(&got.stats.loss), bits(&want.stats.loss), "{ctx}: loss");
    assert_eq!(bits(&got.stats.correct), bits(&want.stats.correct), "{ctx}: correct");
    assert_eq!(bits(&got.stats.conf), bits(&want.stats.conf), "{ctx}: conf");
    match (&got.emb, &want.emb) {
        (Some(g), Some(w)) => assert_eq!(bits(g), bits(w), "{ctx}: emb"),
        (None, None) => {}
        other => panic!("{ctx}: emb presence mismatch: {other:?}"),
    }
    match (&got.probs, &want.probs) {
        (Some(g), Some(w)) => assert_eq!(bits(g), bits(w), "{ctx}: probs"),
        (None, None) => {}
        other => panic!("{ctx}: probs presence mismatch: {other:?}"),
    }
}

/// Equivalence: for random query sets and random batch/replica configs,
/// the coalescing multi-replica fleet answers bitwise identically to
/// per-query single-lane serving, and every query is answered exactly
/// once — including trials where a lane is chaos-killed mid-hammer and
/// its queued queries must redispatch to the survivors.
#[test]
fn batched_fleet_matches_per_query_single_lane_bitwise() {
    let mut rng = Rng::new(0xC0FFEE);
    for trial in 0..6 {
        let replicas = 1 + rng.below(3); // 1..=3 lanes
        let max_batch = 1 + rng.below(8); // 1..=8 coalesced slots
        let kill = replicas > 1 && rng.chance(0.75);
        let n_queries = 16 + rng.below(25); // 16..=40
        let param = rng.normal_f32(0.0, 1.0);
        let ctx = format!(
            "trial {trial}: replicas={replicas} batch={max_batch} kill={kill} n={n_queries}"
        );

        // mixed shapes so the coalescer must group by row width / endpoint
        let queries: Arc<Vec<PropQuery>> = Arc::new(
            (0..n_queries)
                .map(|_| {
                    let rows = 1 + rng.below(3);
                    let dim = 2 + rng.below(2);
                    PropQuery {
                        x: (0..rows * dim).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
                        y: (0..rows).map(|_| rng.below(dim) as i32).collect(),
                        embed: rng.chance(0.4),
                    }
                })
                .collect(),
        );
        let snapshot = Arc::new(Snapshot::params_only(vec![vec![param]]));

        // reference: one lane, no coalescing, strictly sequential queries
        let ref_hub = Arc::new(SnapshotHub::new());
        let ref_fleet = ServeFleet::spawn_single(
            MockBackend::new().replica_builder().unwrap(),
            ref_hub.clone(),
        )
        .unwrap();
        ref_hub.publish(trial, snapshot.clone());
        let ref_pub = ref_hub.latest().unwrap();
        let ref_client = ref_fleet.client();
        let want: Vec<ServeAnswer> = queries
            .iter()
            .map(|q| ref_client.query(ref_pub.clone(), q.x.clone(), q.y.clone(), q.embed).unwrap())
            .collect();

        // subject: R replicas with coalescing on, hammered concurrently
        let hub = Arc::new(SnapshotHub::new());
        let builders = (0..replicas)
            .map(|_| MockBackend::new().replica_builder().unwrap())
            .collect();
        let batching =
            ServeBatching { max_batch, max_wait: Duration::from_millis(3) };
        let mut fleet = ServeFleet::spawn(builders, hub.clone(), batching).unwrap();
        hub.publish(trial, snapshot.clone());
        let published = hub.latest().unwrap();
        let answers: Arc<Mutex<Vec<Option<ServeAnswer>>>> =
            Arc::new(Mutex::new(vec![None; n_queries]));
        let hammers = 4.min(n_queries);
        let threads: Vec<_> = (0..hammers)
            .map(|h| {
                let client = fleet.client();
                let published = published.clone();
                let queries = queries.clone();
                let answers = answers.clone();
                std::thread::spawn(move || {
                    for i in (h..queries.len()).step_by(hammers) {
                        let q = &queries[i];
                        let a = client
                            .query(published.clone(), q.x.clone(), q.y.clone(), q.embed)
                            .unwrap();
                        let prev = answers.lock().unwrap()[i].replace(a);
                        assert!(prev.is_none(), "query {i} answered twice");
                    }
                })
            })
            .collect();
        if kill {
            // land the kill mid-hammer so in-flight queries redispatch
            std::thread::sleep(Duration::from_millis(2));
            fleet.kill_lane(0);
        }
        for t in threads {
            t.join().unwrap();
        }

        let got = answers.lock().unwrap();
        let answered = got.iter().filter(|a| a.is_some()).count();
        assert_eq!(answered, n_queries, "{ctx}: a query went unanswered");
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_answers_bitwise_eq(g.as_ref().unwrap(), w, &format!("{ctx} query {i}"));
        }
        assert_eq!(
            hub.queries_total(),
            n_queries,
            "{ctx}: device forwards double- or under-counted"
        );
        if kill {
            assert_eq!(hub.lanes_down(), 1, "{ctx}");
            assert!(!hub.degraded(), "{ctx}: one dead lane of {replicas} must not degrade");
        }
    }
}

// --- trainer-level (PJRT; skipped when artifacts are absent) -------------

fn runtime() -> Option<XlaRuntime> {
    XlaRuntime::new(&default_artifacts_dir()).ok()
}

fn small_cfg() -> kakurenbo::config::ExperimentConfig {
    let mut cfg = presets::by_name("cifar100_wrn").unwrap();
    cfg.epochs = 4;
    if let DatasetConfig::GaussMixture(ref mut c) = cfg.dataset {
        c.n_train = 512;
        c.n_val = 192;
    }
    cfg.eval_every = 1;
    cfg.strategy = StrategyConfig::kakurenbo(0.3);
    cfg
}

fn assert_records_bitwise_eq(
    a: &kakurenbo::metrics::RunResult,
    b: &kakurenbo::metrics::RunResult,
    ctx: &str,
) {
    assert_eq!(a.records.len(), b.records.len(), "{ctx}");
    for (x, y) in a.records.iter().zip(&b.records) {
        assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits(), "{ctx} epoch {}", x.epoch);
        assert_eq!(x.val_acc.to_bits(), y.val_acc.to_bits(), "{ctx} epoch {}", x.epoch);
        assert_eq!(x.val_loss.to_bits(), y.val_loss.to_bits(), "{ctx} epoch {}", x.epoch);
        assert_eq!(x.hidden, y.hidden, "{ctx} epoch {}", x.epoch);
        assert_eq!(x.moved_back, y.moved_back, "{ctx} epoch {}", x.epoch);
        assert_eq!(x.trained_samples, y.trained_samples, "{ctx} epoch {}", x.epoch);
        assert_eq!(x.lr.to_bits(), y.lr.to_bits(), "{ctx} epoch {}", x.epoch);
    }
}

/// Isolation: `--serve` on vs off — identical records and identical
/// final parameters, alone and composed with `--service-lane on` +
/// `--workers 4` + `--serve-replicas 2` + `--serve-batch 8`.  Serving
/// is a read-only observer of training however the fleet is shaped.
#[test]
fn serving_never_perturbs_training_records() {
    let Some(rt) = runtime() else { return };
    for (service_lane, workers, replicas, batch) in
        [(false, 1usize, 1usize, 1usize), (true, 4, 2, 8)]
    {
        let ctx = format!(
            "service_lane={service_lane} workers={workers} replicas={replicas} batch={batch}"
        );
        let run = |serve: bool| {
            let mut cfg = small_cfg();
            cfg.service_lane = service_lane;
            cfg.workers = workers;
            cfg.serve = serve.then(|| "127.0.0.1:0".to_string());
            cfg.serve_replicas = replicas;
            cfg.serve_batch = batch;
            let mut t = Trainer::new(&rt, cfg).unwrap();
            let result = t.run().unwrap();
            let params = t.exec.export_named_params().unwrap();
            (result, params, t.serve_addr())
        };
        let (r_off, p_off, addr_off) = run(false);
        let (r_on, p_on, addr_on) = run(true);
        assert!(addr_off.is_none(), "{ctx}");
        assert!(addr_on.is_some(), "{ctx}");
        assert_records_bitwise_eq(&r_off, &r_on, &ctx);
        for rec in &r_on.records {
            assert_eq!(rec.serve_publishes, 1, "{ctx} epoch {}", rec.epoch);
        }
        assert!(r_off.records.iter().all(|r| r.serve_publishes == 0), "{ctx}");
        assert_eq!(p_off.len(), p_on.len(), "{ctx}");
        for ((na, da), (nb, db)) in p_off.iter().zip(&p_on) {
            assert_eq!(na, nb, "{ctx}");
            let ba: Vec<u32> = da.iter().map(|v| v.to_bits()).collect();
            let bb: Vec<u32> = db.iter().map(|v| v.to_bits()).collect();
            assert_eq!(ba, bb, "{ctx}: param {na} differs with serving on");
        }
    }
}

/// Fidelity at the executor level: after a real training run, a served
/// `/v1/stats` answer over a validation batch is bitwise identical to
/// calling `fwd_stats` on the training executor directly — the last
/// publication *is* the final parameters.
#[test]
fn served_stats_match_direct_executor_forward() {
    let Some(rt) = runtime() else { return };
    let mut cfg = small_cfg();
    cfg.serve = Some("127.0.0.1:0".to_string());
    let mut t = Trainer::new(&rt, cfg).unwrap();
    t.run().unwrap();
    let addr = t.serve_addr().unwrap();

    let b = t.exec.meta.batch;
    let dim = t.data.val.sample_dim;
    let ll = t.data.val.label_len;
    let x = t.data.val.x[..b * dim].to_vec();
    let y = t.data.val.y[..b * ll].to_vec();
    let rows: Vec<Json> = (0..b)
        .map(|s| Json::from(x[s * dim..(s + 1) * dim].to_vec()))
        .collect();
    let labels: Vec<i64> = y.iter().map(|&l| l as i64).collect();
    let body = kakurenbo::jobj![("x", Json::Arr(rows)), ("y", labels)].to_compact();

    let (status, resp) = http_request(addr, "POST", "/v1/stats", Some(&body)).unwrap();
    assert_eq!(status, 200, "{resp}");
    let v = json::parse(&resp).unwrap();
    assert_eq!(v.get("epoch").unwrap().as_usize(), Some(t.cfg.epochs - 1));
    assert_eq!(v.get("batch").unwrap().as_usize(), Some(b));

    let want = StepBackend::fwd_stats(&mut t.exec, &x, &y).unwrap();
    let want_loss: Vec<u32> = want.loss.iter().map(|l| l.to_bits()).collect();
    let want_conf: Vec<u32> = want.conf.iter().map(|c| c.to_bits()).collect();
    assert_eq!(f32_bits(&v, "loss"), want_loss, "served loss != executor loss");
    assert_eq!(f32_bits(&v, "conf"), want_conf, "served conf != executor conf");

    // /healthz and /v1/snapshot agree on the final epoch
    let (status, resp) = http_request(addr, "GET", "/healthz", None).unwrap();
    assert_eq!(status, 200);
    let h = json::parse(&resp).unwrap();
    assert_eq!(h.get("status").unwrap().as_str(), Some("ok"));
    assert_eq!(h.get("epoch").unwrap().as_usize(), Some(t.cfg.epochs - 1));
    let (status, resp) = http_request(addr, "GET", "/v1/snapshot", None).unwrap();
    assert_eq!(status, 200);
    let s = json::parse(&resp).unwrap();
    assert_eq!(s.get("epoch").unwrap().as_usize(), Some(t.cfg.epochs - 1));
    assert_eq!(s.get("tier").unwrap().as_str(), Some("params"));
}

/// A faulting serving replica follows the run's fault policy.  The
/// substituted [`ServeRuntime`] carries a replica that cannot host the
/// executor's snapshots, so the first query fails on the lane: under
/// `fail` the next epoch barrier aborts the run with the named serve-lane
/// error; under `elastic` the run completes, the failure counts into
/// `service_errors`, and `/healthz` reports `degraded`.
#[test]
fn serve_lane_faults_follow_the_fault_policy() {
    let Some(rt) = runtime() else { return };
    for policy in [FaultPolicy::Fail, FaultPolicy::Elastic] {
        let mut cfg = small_cfg();
        cfg.serve = Some("127.0.0.1:0".to_string());
        cfg.fault_policy = policy;
        let mut t = Trainer::new(&rt, cfg).unwrap();
        // a Mock replica under a real executor's publications: every
        // query forces a params import the replica must reject
        let hub = Arc::new(SnapshotHub::new());
        let fleet =
            ServeFleet::spawn_single(MockBackend::new().replica_builder().unwrap(), hub.clone())
                .unwrap();
        let server =
            InferenceServer::start("127.0.0.1:0", 1, hub.clone(), fleet.client(), None)
                .unwrap();
        let addr = server.addr();
        t.serve = Some(ServeRuntime { server, fleet, hub });

        // hammer the lane from a client thread for the whole run, so a
        // failure lands before an epoch barrier regardless of timing
        let done = Arc::new(AtomicBool::new(false));
        let client = {
            let done = done.clone();
            std::thread::spawn(move || {
                while !done.load(Ordering::Relaxed) {
                    let _ = http_request(
                        addr,
                        "POST",
                        "/v1/stats",
                        Some(r#"{"x": [[1.0, 2.0]], "y": [0]}"#),
                    );
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
            })
        };
        let outcome = t.run();
        match policy {
            FaultPolicy::Fail => {
                let err = outcome.unwrap_err().to_string();
                assert!(err.contains("service serve lane failed"), "{err}");
                assert!(err.contains("--fault-policy"), "{err}");
            }
            FaultPolicy::Elastic => {
                let result = outcome.unwrap();
                let errors: usize =
                    result.records.iter().map(|r| r.service_errors).sum();
                assert!(errors >= 1, "no serve failure folded into the records");
                let (status, resp) = http_request(addr, "GET", "/healthz", None).unwrap();
                assert_eq!(status, 200);
                let v = json::parse(&resp).unwrap();
                assert_eq!(
                    v.get("status").unwrap().as_str(),
                    Some("degraded"),
                    "{resp}"
                );
            }
        }
        done.store(true, Ordering::Relaxed);
        client.join().unwrap();
    }
}
