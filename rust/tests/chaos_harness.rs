//! The chaos-injection harness: kill / delay / rejoin matrices over the
//! worker pool's fault policies (ISSUE 7's headline suite).
//!
//! Every elastic-recovery run here must end **bitwise identical** to the
//! undisturbed run over the same logical `(step, worker)` epoch order —
//! parameters, update trace, and eval sums — and every `--fault-policy
//! fail` run must abort with a named error instead of hanging.  Faults
//! are scripted through the seeded [`ChaosPlan`] layer
//! (`engine/chaos.rs`): gather lanes consult the plan directly, device
//! faults ride the [`ChaosBackend`] wrapper threaded through
//! `StepBackend` / `ReplicaBuilder`.
//!
//! The `KAKURENBO_CHAOS_SEED` environment variable (CI's seed matrix)
//! narrows the randomized-plan test to one seed; unset, a fixed
//! three-seed matrix runs.  The end-to-end resume-after-chaos test is
//! skipped (not failed) when the PJRT artifacts are absent, like every
//! other executor-bound suite.

use kakurenbo::config::{presets, DatasetConfig, StrategyConfig};
use kakurenbo::coordinator::Trainer;
use kakurenbo::data::shard::{shard_order_aligned, Shard};
use kakurenbo::data::synth::{gauss_mixture, GaussMixtureCfg};
use kakurenbo::data::Dataset;
use kakurenbo::engine::testbed::MockBackend;
use kakurenbo::engine::{
    ChaosBackend, ChaosPlan, DataParallel, EvalSink, ServeFleet, ServiceEvent, ServiceLaneKind,
    ServiceLanes, SnapshotHub, StateExchange, StepBackend, StepMode, WorkerPool,
};
use kakurenbo::runtime::{default_artifacts_dir, XlaRuntime};
use kakurenbo::serve::{http_request, InferenceServer};
use kakurenbo::util::json;

const B: usize = 8;
/// Straggler timeout used by delay cells; injected delays are 2x this.
const TIMEOUT_MS: u64 = 150;

fn tiny(n: usize) -> Dataset {
    gauss_mixture(
        &GaussMixtureCfg { n_train: n, n_val: 21, dim: 6, classes: 3, ..Default::default() },
        7,
    )
    .train
}

/// What one pool run produced, reduced to bit patterns for comparison.
struct RunOut {
    param_bits: u32,
    trace: Vec<u64>,
    acc_bits: u64,
    loss_bits: u64,
    dropped: usize,
    rejoined: usize,
}

/// One serial-equivalent run: chaos (if any) armed on the pool's gather
/// lanes, fault policy + straggler timeout as given.
fn serial_run(
    d: &Dataset,
    shards: &[Shard],
    chaos: Option<ChaosPlan>,
    elastic: bool,
    timeout_ms: u64,
    mode: StepMode,
) -> anyhow::Result<RunOut> {
    let mut pool = WorkerPool::new(d, B);
    pool.set_fault_policy(elastic, timeout_ms);
    if let Some(plan) = chaos {
        pool.inject_chaos(plan);
    }
    let mut be = MockBackend::new();
    let mut sink = EvalSink::default();
    let out = pool.run_serial_equivalent(&mut be, d, shards, mode, &mut sink)?;
    let (acc, loss) = sink.result();
    Ok(RunOut {
        param_bits: be.param.to_bits(),
        trace: be.trace,
        acc_bits: acc.to_bits(),
        loss_bits: loss.to_bits(),
        dropped: out.dropped_lanes,
        rejoined: out.rejoined_lanes,
    })
}

/// One `--dp average`-style run: the primary (and thus every replica the
/// pool builds from it) wears a [`ChaosBackend`] carrying `plan` — an
/// empty plan is a pure delegate, so the same wrapper serves as the
/// undisturbed reference.
fn dp_run(
    d: &Dataset,
    shards: &[Shard],
    plan: ChaosPlan,
    elastic: bool,
    timeout_ms: u64,
    mode: StepMode,
) -> anyhow::Result<RunOut> {
    let mut pool = WorkerPool::new(d, B);
    pool.set_fault_policy(elastic, timeout_ms);
    let mut be = ChaosBackend::primary(MockBackend::new(), plan);
    let mut sink = EvalSink::default();
    let out = pool.run_data_parallel(&mut be, d, shards, mode, &mut sink)?;
    let (acc, loss) = sink.result();
    Ok(RunOut {
        param_bits: be.inner().param.to_bits(),
        // the primary's update trace is not comparable here: under
        // elastic recovery it legitimately executes the adopted steps
        // (the averaged *parameters* are the identity contract)
        trace: Vec::new(),
        acc_bits: acc.to_bits(),
        loss_bits: loss.to_bits(),
        dropped: out.dropped_lanes,
        rejoined: out.rejoined_lanes,
    })
}

fn assert_bitwise_eq(a: &RunOut, b: &RunOut, ctx: &str) {
    assert_eq!(a.param_bits, b.param_bits, "final params differ: {ctx}");
    assert_eq!(a.trace, b.trace, "update trace differs: {ctx}");
    assert_eq!(a.acc_bits, b.acc_bits, "eval acc differs: {ctx}");
    assert_eq!(a.loss_bits, b.loss_bits, "eval loss differs: {ctx}");
}

/// Kill-at-step ∈ {first, mid, last} given a lane's step count.
fn kill_points(steps: usize) -> Vec<usize> {
    let mut pts = vec![0, steps / 2, steps - 1];
    pts.dedup();
    pts
}

/// The acceptance matrix, serial-equivalent schedule: W∈{2,4} ×
/// kill-at-step ∈ {first, mid, last} × delay ∈ {0, 2×timeout}.  Every
/// elastic recovery ends bitwise identical to the undisturbed run.
#[test]
fn serial_kill_delay_matrix_recovers_bitwise() {
    let mode = StepMode::Train { lr: 0.05 };
    for w in [2usize, 4] {
        let d = tiny(97);
        let order: Vec<u32> = (0..97u32).rev().collect();
        let shards = shard_order_aligned(&order, w, B);
        let steps = shards[0].steps(B);
        let base = serial_run(&d, &shards, None, false, 0, mode).unwrap();
        for kill_at in kill_points(steps) {
            for delay_ms in [0u64, 2 * TIMEOUT_MS] {
                let victim = w - 1;
                let mut plan = ChaosPlan::new().kill(victim, kill_at);
                let timeout = if delay_ms > 0 {
                    // a second lane stalls past the timeout at the same
                    // step: both faults recover in one run
                    plan = plan.delay(0, kill_at, delay_ms);
                    TIMEOUT_MS
                } else {
                    0
                };
                let ctx = format!("W={w} kill@{kill_at} delay={delay_ms}ms");
                let run = serial_run(&d, &shards, Some(plan), true, timeout, mode)
                    .unwrap_or_else(|e| panic!("{ctx}: {e}"));
                // detection timing never affects the result: a straggler
                // caught late (or delivering just under the timeout on a
                // stalled CI host) still folds bitwise identically, so
                // only the guaranteed kill is asserted on counts
                assert!(run.dropped >= 1, "{ctx}: no lane dropped");
                assert_eq!(run.dropped, run.rejoined, "{ctx}");
                assert_bitwise_eq(&run, &base, &ctx);
            }
        }
    }
}

/// The acceptance matrix, `--dp average` schedule: a replica killed at
/// {first, mid, last} step has its remaining steps adopted by the
/// primary from the pre-step snapshot; the averaged trajectory stays
/// bitwise identical.  The delay cells stall a replica past the
/// straggler timeout instead.
#[test]
fn dp_average_kill_delay_matrix_recovers_bitwise() {
    let mode = StepMode::Train { lr: 0.05 };
    for w in [2usize, 4] {
        let d = tiny(97);
        let order: Vec<u32> = (0..97u32).collect();
        let shards = shard_order_aligned(&order, w, B);
        let steps = shards[0].steps(B);
        let base = dp_run(&d, &shards, ChaosPlan::new(), false, 0, mode).unwrap();
        for kill_at in kill_points(steps) {
            let ctx = format!("W={w} replica-kill@{kill_at}");
            let plan = ChaosPlan::new().kill(w - 1, kill_at);
            let run = dp_run(&d, &shards, plan, true, 0, mode)
                .unwrap_or_else(|e| panic!("{ctx}: {e}"));
            assert_eq!(run.dropped, 1, "{ctx}");
            assert_eq!(run.rejoined, 1, "{ctx}");
            assert_bitwise_eq(&run, &base, &ctx);
        }
        // delay cell: replica 0 stalls 2x the timeout mid-run; whether
        // the timeout trips before the late reply lands (host timing),
        // the folded trajectory must stay bitwise identical
        let ctx = format!("W={w} replica-delay");
        let plan = ChaosPlan::new().delay(0, steps / 2, 2 * TIMEOUT_MS);
        let run = dp_run(&d, &shards, plan, true, TIMEOUT_MS, mode)
            .unwrap_or_else(|e| panic!("{ctx}: {e}"));
        assert_bitwise_eq(&run, &base, &ctx);
    }
}

/// A scripted one-shot state-export failure (the third [`ChaosAction`])
/// on a replica: elastic recovery re-executes the step on the primary —
/// bitwise identical — while the fail policy aborts with the named
/// chaos error.
///
/// [`ChaosAction`]: kakurenbo::engine::ChaosAction
#[test]
fn dp_export_failure_recovers_elastically_and_aborts_under_fail() {
    let mode = StepMode::Train { lr: 0.05 };
    let d = tiny(53);
    let order: Vec<u32> = (0..53u32).collect();
    let shards = shard_order_aligned(&order, 2, B);
    let base = dp_run(&d, &shards, ChaosPlan::new(), false, 0, mode).unwrap();

    let plan = ChaosPlan::new().fail_export(1, 1);
    let run = dp_run(&d, &shards, plan.clone(), true, 0, mode).unwrap();
    assert_eq!(run.dropped, 1);
    assert_bitwise_eq(&run, &base, "fail_export elastic");

    let err = dp_run(&d, &shards, plan, false, 0, mode).unwrap_err().to_string();
    assert!(err.contains("worker 1 step failed"), "{err}");
    assert!(err.contains("state export failed"), "{err}");
}

/// `--fault-policy fail` aborts with a named error — never a hang — on
/// both schedules and both fault types.
#[test]
fn fail_policy_aborts_with_named_errors() {
    let d = tiny(53);
    let order: Vec<u32> = (0..53u32).collect();
    let shards = shard_order_aligned(&order, 2, B);
    let mode = StepMode::Train { lr: 0.05 };

    let err = serial_run(&d, &shards, Some(ChaosPlan::new().kill(1, 1)), false, 0, mode)
        .unwrap_err()
        .to_string();
    assert!(err.contains("worker 1 gather lane died at step 1"), "{err}");
    assert!(err.contains("--fault-policy"), "{err}");

    let err = serial_run(
        &d,
        &shards,
        Some(ChaosPlan::new().delay(0, 0, 4 * TIMEOUT_MS)),
        false,
        TIMEOUT_MS,
        mode,
    )
    .unwrap_err()
    .to_string();
    assert!(err.contains("straggler timeout"), "{err}");

    let err = dp_run(&d, &shards, ChaosPlan::new().kill(0, 0), false, 0, mode)
        .unwrap_err()
        .to_string();
    assert!(err.contains("worker 0 step failed"), "{err}");
    assert!(err.contains("chaos"), "{err}");
}

/// Seeded random plans (the CI seed matrix): whatever lane and step the
/// plan picks, elastic recovery stays bitwise identical.  Honors
/// `KAKURENBO_CHAOS_SEED`; unset, a fixed three-seed matrix runs.
#[test]
fn randomized_seed_matrix_recovers_bitwise() {
    let seeds: Vec<u64> = match std::env::var("KAKURENBO_CHAOS_SEED") {
        Ok(s) => vec![s.parse().expect("KAKURENBO_CHAOS_SEED must be a u64")],
        Err(_) => vec![1101, 2202, 3303],
    };
    let mode = StepMode::Train { lr: 0.03 };
    for seed in seeds {
        for w in [2usize, 4] {
            let d = tiny(97);
            let order: Vec<u32> = (0..97u32).rev().collect();
            let shards = shard_order_aligned(&order, w, B);
            let steps = shards[0].steps(B);
            let plan = ChaosPlan::randomized(seed ^ w as u64, w, steps);
            assert!(!plan.is_empty(), "randomized plan must inject something");
            let ctx = format!("seed={seed} W={w} plan={:?}", plan.events());
            let base = serial_run(&d, &shards, None, false, 0, mode).unwrap();
            let run = serial_run(&d, &shards, Some(plan), true, 0, mode)
                .unwrap_or_else(|e| panic!("{ctx}: {e}"));
            assert!(run.dropped >= 1, "{ctx}");
            assert_bitwise_eq(&run, &base, &ctx);
        }
    }
}

/// Embed-mode coverage (PFB's feature-harvest sweep) over the kill /
/// delay matrix: the committed feature cache — the scoring input every
/// later PFB epoch prunes from — and the stat refresh the sweep doubles
/// as must come out bitwise identical under elastic lane re-issue.  A
/// chaos-hit harvest that drifted by one bit would silently shift every
/// pruning decision until the next refresh.
#[test]
fn embed_harvest_kill_delay_matrix_commits_bitwise_identical_cache() {
    use kakurenbo::engine::execute_sharded_harvest;
    use kakurenbo::state::{FeatureCache, SampleState};

    const N: usize = 97;
    const HARVEST_EPOCH: u32 = 5;
    let harvest = |w: usize,
                   chaos: Option<ChaosPlan>,
                   elastic: bool,
                   timeout_ms: u64|
     -> anyhow::Result<(Vec<u32>, Vec<u32>, usize, usize)> {
        let d = tiny(N);
        let order: Vec<u32> = (0..N as u32).collect();
        let shards = shard_order_aligned(&order, w, B);
        let mut pool = WorkerPool::new(&d, B);
        pool.set_fault_policy(elastic, timeout_ms);
        if let Some(plan) = chaos {
            pool.inject_chaos(plan);
        }
        let mut be = MockBackend::new();
        let mut state = SampleState::new(N);
        let mut cache = FeatureCache::new(N);
        let out = execute_sharded_harvest(
            &mut pool,
            &mut be,
            &d,
            &shards,
            HARVEST_EPOCH,
            &mut state,
            &mut cache,
        )?;
        let (_dim, epoch, rows) = cache.export().expect("harvest must commit the cache");
        assert_eq!(epoch, HARVEST_EPOCH);
        Ok((
            rows.iter().map(|v| v.to_bits()).collect(),
            state.loss.iter().map(|v| v.to_bits()).collect(),
            out.dropped_lanes,
            out.rejoined_lanes,
        ))
    };

    for w in [2usize, 4] {
        let order: Vec<u32> = (0..N as u32).collect();
        let steps = shard_order_aligned(&order, w, B)[0].steps(B);
        let (base_rows, base_loss, _, _) = harvest(w, None, false, 0).unwrap();
        for kill_at in kill_points(steps) {
            for delay_ms in [0u64, 2 * TIMEOUT_MS] {
                let mut plan = ChaosPlan::new().kill(w - 1, kill_at);
                let timeout = if delay_ms > 0 {
                    plan = plan.delay(0, kill_at, delay_ms);
                    TIMEOUT_MS
                } else {
                    0
                };
                let ctx = format!("embed W={w} kill@{kill_at} delay={delay_ms}ms");
                let (rows, loss, dropped, rejoined) =
                    harvest(w, Some(plan), true, timeout).unwrap_or_else(|e| panic!("{ctx}: {e}"));
                assert!(dropped >= 1, "{ctx}: no lane dropped");
                assert_eq!(dropped, rejoined, "{ctx}");
                assert_eq!(rows, base_rows, "feature rows drifted: {ctx}");
                assert_eq!(loss, base_loss, "refreshed stats drifted: {ctx}");
            }
        }
    }
}

/// Embed mode never crosses replica lanes: the data-parallel schedule
/// rejects it up front with the documented error (lane replies carry
/// stats only), and the fail policy on the serial-equivalent schedule
/// still aborts a killed harvest by name instead of committing a
/// partial cache.
#[test]
fn embed_mode_dp_rejection_and_fail_policy_are_named_errors() {
    let d = tiny(53);
    let order: Vec<u32> = (0..53u32).collect();
    let shards = shard_order_aligned(&order, 2, B);

    let err = dp_run(&d, &shards, ChaosPlan::new(), false, 0, StepMode::Embed)
        .unwrap_err()
        .to_string();
    assert!(err.contains("serial-equivalent schedule only"), "{err}");

    let err =
        serial_run(&d, &shards, Some(ChaosPlan::new().kill(1, 0)), false, 0, StepMode::Embed)
            .unwrap_err()
            .to_string();
    assert!(err.contains("worker 1 gather lane died at step 0"), "{err}");
}

/// Chaos composes with ragged shards (the satellite deadlock fix): a
/// kill on the long lane of a maximally ragged layout still recovers
/// bitwise, with the short lane long since retired from the barrier.
#[test]
fn ragged_shards_with_chaos_recover_bitwise() {
    let d = tiny(32);
    let shards = vec![
        Shard { worker: 0, indices: (0..24).collect() }, // 3 steps
        Shard { worker: 1, indices: (24..26).collect() }, // 1 ragged step
    ];
    let mode = StepMode::Train { lr: 0.05 };
    let base = serial_run(&d, &shards, None, false, 0, mode).unwrap();
    let run =
        serial_run(&d, &shards, Some(ChaosPlan::new().kill(0, 2)), true, 0, mode).unwrap();
    assert_eq!(run.dropped, 1);
    assert_bitwise_eq(&run, &base, "ragged + kill long lane");
}

/// Service-lane configuration: a chaos-killed eval job surfaces as one
/// named [`ServiceEvent::Error`] and the lane keeps serving — the next
/// eval of the same snapshot is bitwise identical to an undisturbed
/// lane's.
#[test]
fn chaos_killed_eval_job_is_isolated_to_one_error_event() {
    let val = gauss_mixture(
        &GaussMixtureCfg { n_train: 8, n_val: 21, dim: 6, classes: 3, ..Default::default() },
        7,
    )
    .val;
    let snap = std::sync::Arc::new(kakurenbo::engine::Snapshot::params_only(vec![vec![1.5]]));

    // undisturbed reference lane
    let clean = ChaosBackend::primary(MockBackend::new(), ChaosPlan::new());
    let mut ref_lanes =
        ServiceLanes::spawn(clean.replica_builder().unwrap(), val.clone(), B, None).unwrap();
    ref_lanes.submit_eval(0, snap.clone()).unwrap();
    let ref_events = ref_lanes.drain().unwrap();
    let (ref_acc, ref_loss) = match &ref_events[0] {
        ServiceEvent::Eval { acc, loss, .. } => (acc.to_bits(), loss.to_bits()),
        other => panic!("unexpected event {other:?}"),
    };

    // chaos lane: the eval replica (rank 0) dies on its second forward
    // call, failing exactly the first submitted job
    let chaotic = ChaosBackend::primary(MockBackend::new(), ChaosPlan::new().kill(0, 1));
    let mut lanes =
        ServiceLanes::spawn(chaotic.replica_builder().unwrap(), val, B, None).unwrap();
    lanes.submit_eval(0, snap.clone()).unwrap();
    lanes.submit_eval(1, snap).unwrap();
    let events = lanes.drain().unwrap();
    match &events[0] {
        ServiceEvent::Error { epoch: 0, lane: ServiceLaneKind::Eval, message, .. } => {
            assert!(message.contains("chaos"), "{message}");
        }
        other => panic!("expected an eval error event, got {other:?}"),
    }
    match &events[1] {
        ServiceEvent::Eval { epoch: 1, acc, loss, .. } => {
            assert_eq!(acc.to_bits(), ref_acc, "post-fault eval drifted");
            assert_eq!(loss.to_bits(), ref_loss, "post-fault eval drifted");
        }
        other => panic!("expected a recovered eval, got {other:?}"),
    }
}

/// Serve-lane configuration: a chaos-killed serving replica answers the
/// in-flight HTTP query with a named 500, flips `/healthz` to degraded,
/// and puts exactly one [`ServiceEvent::Error`] tagged with the serve
/// lane into the fold-in stream — then keeps serving, and the post-fault
/// answer is bitwise identical to an undisturbed backend's.
#[test]
fn chaos_killed_serve_replica_degrades_health_but_keeps_serving() {
    use std::sync::Arc;

    // undisturbed reference answer for the same batch + params
    let mut direct = MockBackend::new();
    direct.import_params(&[vec![1.5]]).unwrap();
    let want = direct.fwd_stats(&[0.5, 0.25], &[1]).unwrap();

    // the serving replica dies on its second forward call (imports count
    // no device steps, same accounting as the eval-lane cell above)
    let hub = Arc::new(SnapshotHub::new());
    let chaotic = ChaosBackend::primary(MockBackend::new(), ChaosPlan::new().kill(0, 1));
    let mut fleet =
        ServeFleet::spawn_single(chaotic.replica_builder().unwrap(), hub.clone()).unwrap();
    let srv = InferenceServer::start("127.0.0.1:0", 2, hub.clone(), fleet.client(), None).unwrap();
    hub.publish(4, Arc::new(kakurenbo::engine::Snapshot::params_only(vec![vec![1.5]])));

    let body = r#"{"x": [[0.5, 0.25]], "y": [1]}"#;
    let (code, text) = http_request(srv.addr(), "POST", "/v1/stats", Some(body)).unwrap();
    assert_eq!(code, 200, "healthy first answer: {text}");

    // second forward: the kill fires — named 500, degraded health
    let (code, text) = http_request(srv.addr(), "POST", "/v1/stats", Some(body)).unwrap();
    assert_eq!(code, 500, "{text}");
    assert!(text.contains("chaos"), "{text}");
    let (code, text) = http_request(srv.addr(), "GET", "/healthz", None).unwrap();
    assert_eq!(code, 200, "{text}");
    let health = json::parse(&text).unwrap();
    assert_eq!(health.get("status").unwrap().as_str(), Some("degraded"));

    // exactly one fold-in error, tagged with the serve lane
    let events = fleet.try_events();
    assert_eq!(events.len(), 1, "{events:?}");
    match &events[0] {
        ServiceEvent::Error { epoch: 4, lane: ServiceLaneKind::Serve, message, .. } => {
            assert!(message.contains("chaos"), "{message}");
        }
        other => panic!("expected a serve error event, got {other:?}"),
    }

    // the one-shot kill has fired: the lane recovers and the answer is
    // bitwise identical to the undisturbed reference
    let (code, text) = http_request(srv.addr(), "POST", "/v1/stats", Some(body)).unwrap();
    assert_eq!(code, 200, "{text}");
    let v = json::parse(&text).unwrap();
    let loss = v.get("loss").unwrap().as_arr().unwrap()[0].as_f64().unwrap() as f32;
    assert_eq!(loss.to_bits(), want.loss[0].to_bits(), "post-fault answer drifted");
}

// --- end-to-end: resume after a chaos-killed run (PJRT-gated) --------------

fn runtime() -> Option<XlaRuntime> {
    XlaRuntime::new(&default_artifacts_dir()).ok()
}

fn small_cfg() -> kakurenbo::config::ExperimentConfig {
    let mut cfg = presets::by_name("cifar100_wrn").unwrap();
    cfg.epochs = 6;
    if let DatasetConfig::GaussMixture(ref mut c) = cfg.dataset {
        c.n_train = 512;
        c.n_val = 128;
    }
    cfg.eval_every = 1;
    cfg
}

/// Satellite: `--resume` after a chaos-killed run mid-epoch replays
/// bit-exactly from the last committed checkpoint generation.  The kill
/// lands in epoch 3 *after* the epoch-2 checkpoint committed; under the
/// default fail policy the run aborts with the named error (parameters
/// already perturbed past the checkpoint), and resume replays epochs
/// 3..6 bitwise identical to the uninterrupted run.
#[test]
fn resume_after_chaos_kill_replays_bit_exactly() {
    let Some(rt) = runtime() else { return };
    let dir = std::env::temp_dir()
        .join(format!("kakurenbo_chaos_resume_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();

    let mut cfg = small_cfg();
    cfg.strategy = StrategyConfig::Baseline;
    cfg.workers = 2;
    cfg.checkpoint_every = 2;
    cfg.checkpoint_dir = Some(dir.clone());

    // uninterrupted reference run (same seed, no checkpointing)
    let mut ref_cfg = cfg.clone();
    ref_cfg.checkpoint_every = 0;
    ref_cfg.checkpoint_dir = None;
    let mut full = Trainer::new(&rt, ref_cfg).unwrap();
    let full_result = full.run().unwrap();

    // the "interrupted" run: checkpoints commit at epochs 0 and 2, then
    // chaos kills gather lane 1 at epoch 3's first step and the fail
    // policy aborts mid-epoch
    {
        let mut t = Trainer::new(&rt, cfg.clone()).unwrap();
        for epoch in 0..3 {
            t.run_epoch(epoch).unwrap();
        }
        t.pool.inject_chaos(ChaosPlan::new().kill(1, 0));
        let err = t.run_epoch(3).unwrap_err().to_string();
        assert!(err.contains("gather lane died"), "{err}");
    }

    // resume replays from the epoch-2 generation
    cfg.resume = true;
    let mut resumed = Trainer::new(&rt, cfg).unwrap();
    let resumed_result = resumed.run().unwrap();
    assert_eq!(resumed_result.records.first().unwrap().epoch, 3);
    let tail = &full_result.records[3..];
    assert_eq!(resumed_result.records.len(), tail.len());
    for (x, y) in resumed_result.records.iter().zip(tail) {
        assert_eq!(x.epoch, y.epoch);
        assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits(), "epoch {}", x.epoch);
        assert_eq!(x.val_acc.to_bits(), y.val_acc.to_bits(), "epoch {}", x.epoch);
        assert_eq!(x.val_loss.to_bits(), y.val_loss.to_bits(), "epoch {}", x.epoch);
        assert_eq!(x.trained_samples, y.trained_samples, "epoch {}", x.epoch);
    }
    let pa = full.exec.export_named_params().unwrap();
    let pb = resumed.exec.export_named_params().unwrap();
    assert_eq!(pa.len(), pb.len());
    for ((na, da), (nb, db)) in pa.iter().zip(&pb) {
        assert_eq!(na, nb);
        let ba: Vec<u32> = da.iter().map(|v| v.to_bits()).collect();
        let bb: Vec<u32> = db.iter().map(|v| v.to_bits()).collect();
        assert_eq!(ba, bb, "param {na} differs after resume");
    }
    std::fs::remove_dir_all(&dir).ok();
}
