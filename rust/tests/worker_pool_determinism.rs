//! Worker-pool determinism: an N-worker pool run must produce *bitwise
//! identical* epoch records to the single-stream interleaved run — the
//! contract that makes `--workers` a pure execution knob.
//!
//! The reference for W workers is the pipelined engine driven over
//! `global_batch_order(shard_order_aligned(order, W, B), B)`: the exact
//! device-call sequence the pre-pool trainer performed when simulating W
//! virtual workers on one stream.  The pool must reproduce every recorded
//! bit — per-sample state, epoch mean loss, and the backend's parameter
//! trace — for the train pass and the hidden-stat refresh, across epochs.
//!
//! The data-parallel (parameter-averaging) schedule is additionally
//! checked for bitwise forward equivalence and run-to-run train
//! determinism.  A final runtime-guarded test repeats the reproducibility
//! check end-to-end through the real PJRT executor.

use kakurenbo::data::shard::{global_batch_order, shard_order_aligned};
use kakurenbo::data::synth::{gauss_mixture, GaussMixtureCfg};
use kakurenbo::data::Dataset;
use kakurenbo::engine::testbed::MockBackend;
use kakurenbo::engine::{
    execute_plan, execute_sharded_plain, Engine, RefreshSink, StepMode, WorkerPool,
};
use kakurenbo::state::SampleState;
use kakurenbo::strategies::sb::SbSelector;
use kakurenbo::strategies::BatchMode;
use kakurenbo::util::rng::Rng;

const B: usize = 8;
const N: usize = 83; // not divisible by W*B: exercises wrap-around padding

fn dataset() -> Dataset {
    gauss_mixture(
        &GaussMixtureCfg { n_train: N, n_val: 16, dim: 5, classes: 4, ..Default::default() },
        11,
    )
    .train
}

fn order(seed: u64) -> Vec<u32> {
    let mut rng = Rng::new(seed);
    kakurenbo::sampler::epoch_permutation(N, &mut rng)
}

/// All recorded f32 state as bit patterns (bitwise comparison).
fn state_bits(s: &SampleState) -> (Vec<u32>, Vec<bool>, Vec<u32>, Vec<u32>) {
    (
        s.loss.iter().map(|l| l.to_bits()).collect(),
        s.correct.clone(),
        s.conf.iter().map(|c| c.to_bits()).collect(),
        s.last_update_epoch.clone(),
    )
}

/// Reference: the single-stream interleaved run for W workers — the
/// pipelined engine over the batch-granular interleave of the shards.
fn reference_train(
    w: usize,
    epoch_orders: &[Vec<u32>],
) -> ((Vec<u32>, Vec<bool>, Vec<u32>, Vec<u32>), Vec<u64>, u32, Vec<u64>) {
    let d = dataset();
    let mut be = MockBackend::new();
    let mut state = SampleState::new(N);
    let mut eng = Engine::new(&d, B);
    eng.overlap = true;
    let mut sb = SbSelector::new(1.0, 64);
    let mut rng = Rng::new(5);
    let mut queue = Vec::new();
    let mut losses = Vec::new();
    for (e, order) in epoch_orders.iter().enumerate() {
        let shards = shard_order_aligned(order, w, B);
        let flat = global_batch_order(&shards, B);
        let out = execute_plan(
            &mut eng,
            &mut be,
            &d,
            &flat,
            None,
            BatchMode::Plain,
            0.05 / (1.0 + e as f32),
            e as u32,
            &mut state,
            &mut sb,
            &mut rng,
            &mut queue,
        )
        .unwrap();
        losses.push(out.train_loss.to_bits());
    }
    (state_bits(&state), be.trace.clone(), be.param.to_bits(), losses)
}

/// The W-worker pool run over the same epochs.
fn pool_train(
    w: usize,
    epoch_orders: &[Vec<u32>],
) -> ((Vec<u32>, Vec<bool>, Vec<u32>, Vec<u32>), Vec<u64>, u32, Vec<u64>) {
    let d = dataset();
    let mut be = MockBackend::new();
    let mut state = SampleState::new(N);
    let mut pool = WorkerPool::new(&d, B);
    let mut losses = Vec::new();
    for (e, order) in epoch_orders.iter().enumerate() {
        let shards = shard_order_aligned(order, w, B);
        let (out, pout) = execute_sharded_plain(
            &mut pool,
            &mut be,
            &d,
            &shards,
            0.05 / (1.0 + e as f32),
            e as u32,
            &mut state,
        )
        .unwrap();
        assert_eq!(pout.workers.len(), w);
        assert_eq!(
            pout.workers.iter().map(|r| r.samples).sum::<usize>(),
            out.trained_samples
        );
        losses.push(out.train_loss.to_bits());
    }
    (state_bits(&state), be.trace.clone(), be.param.to_bits(), losses)
}

/// The acceptance contract: a W-worker pool run produces bitwise-identical
/// epoch records (per-sample state, mean loss, parameter trajectory) to
/// the interleaved single-stream run, across a multi-epoch chain.
#[test]
fn pool_train_bitwise_matches_interleaved_stream() {
    let epoch_orders: Vec<Vec<u32>> = (0..3).map(|e| order(100 + e)).collect();
    for w in [1usize, 2, 4] {
        let reference = reference_train(w, &epoch_orders);
        let pooled = pool_train(w, &epoch_orders);
        assert_eq!(reference.0, pooled.0, "state diverged at W={w}");
        assert_eq!(reference.1, pooled.1, "param trace diverged at W={w}");
        assert_eq!(reference.2, pooled.2, "final param diverged at W={w}");
        assert_eq!(reference.3, pooled.3, "epoch losses diverged at W={w}");
    }
}

/// Odd worker counts exercise shards whose wrap padding overlaps several
/// windows; the contract is worker-count agnostic.
#[test]
fn pool_train_matches_for_odd_worker_counts() {
    let epoch_orders = vec![order(7)];
    for w in [3usize, 5] {
        assert_eq!(reference_train(w, &epoch_orders), pool_train(w, &epoch_orders));
    }
}

/// Forward-only refresh: the pool's sharded hidden-list refresh records
/// exactly the bits of the single-stream refresh over the interleave.
#[test]
fn pool_refresh_bitwise_matches_interleaved_stream() {
    let d = dataset();
    let hidden: Vec<u32> = (0..N as u32).filter(|i| i % 3 == 0).collect();
    for w in [2usize, 4] {
        let shards = shard_order_aligned(&hidden, w, B);

        let mut ref_be = MockBackend::new();
        let mut ref_state = SampleState::new(N);
        let mut eng = Engine::new(&d, B);
        eng.overlap = true;
        let flat = global_batch_order(&shards, B);
        let mut sink = RefreshSink::new(&mut ref_state, 4);
        eng.run(&mut ref_be, &d, &flat, None, StepMode::Forward, &mut sink).unwrap();

        let mut be = MockBackend::new();
        let mut state = SampleState::new(N);
        let mut pool = WorkerPool::new(&d, B);
        let mut sink = RefreshSink::new(&mut state, 4);
        pool.run_serial_equivalent(&mut be, &d, &shards, StepMode::Forward, &mut sink)
            .unwrap();

        assert_eq!(state_bits(&ref_state), state_bits(&state), "W={w}");
    }
}

/// Wrap-padding duplicates in a sharded refresh re-record identical
/// values: the resulting state equals the unsharded refresh bit for bit.
#[test]
fn sharded_refresh_padding_is_semantically_invisible() {
    let d = dataset();
    let hidden: Vec<u32> = (0..N as u32).filter(|i| i % 2 == 0).collect();

    let mut be = MockBackend::new();
    let mut plain = SampleState::new(N);
    let mut eng = Engine::new(&d, B);
    let mut sink = RefreshSink::new(&mut plain, 2);
    eng.run(&mut be, &d, &hidden, None, StepMode::Forward, &mut sink).unwrap();

    let mut be = MockBackend::new();
    let mut sharded = SampleState::new(N);
    let mut pool = WorkerPool::new(&d, B);
    let shards = shard_order_aligned(&hidden, 4, B);
    let mut sink = RefreshSink::new(&mut sharded, 2);
    pool.run_serial_equivalent(&mut be, &d, &shards, StepMode::Forward, &mut sink)
        .unwrap();

    assert_eq!(state_bits(&plain), state_bits(&sharded));
}

/// Heavy hiding fractions can shrink an epoch below the worker count (or
/// empty it entirely); the pool must not panic or deadlock.
#[test]
fn tiny_and_empty_epochs_survive_the_pool() {
    let d = dataset();
    for w in [2usize, 4] {
        let mut pool = WorkerPool::new(&d, B);
        for order_len in [0usize, 1, 3, 7] {
            let order: Vec<u32> = (0..order_len as u32).collect();
            let shards = shard_order_aligned(&order, w, B);
            let mut be = MockBackend::new();
            let mut state = SampleState::new(N);
            let (out, pout) = execute_sharded_plain(
                &mut pool, &mut be, &d, &shards, 0.01, 0, &mut state,
            )
            .unwrap();
            if order_len == 0 {
                assert_eq!(out.trained_samples, 0);
            } else {
                assert_eq!(out.trained_samples, w * B); // wrap-padded
            }
            assert_eq!(pout.workers.len(), w);
        }
    }
}

/// The data-parallel (replica) schedule is bitwise serial-equivalent for
/// forward passes and deterministic run-to-run for train passes.
#[test]
fn data_parallel_schedule_contracts() {
    let d = dataset();
    let idx: Vec<u32> = (0..N as u32).collect();
    for w in [2usize, 4] {
        let shards = shard_order_aligned(&idx, w, B);
        let mut pool = WorkerPool::new(&d, B);

        // forward: replicas hold identical parameters => bitwise equal
        let mut be_a = MockBackend::new();
        let mut st_a = SampleState::new(N);
        let mut sink = RefreshSink::new(&mut st_a, 1);
        pool.run_serial_equivalent(&mut be_a, &d, &shards, StepMode::Forward, &mut sink)
            .unwrap();
        let mut be_b = MockBackend::new();
        let mut st_b = SampleState::new(N);
        let mut sink = RefreshSink::new(&mut st_b, 1);
        pool.run_data_parallel(&mut be_b, &d, &shards, StepMode::Forward, &mut sink)
            .unwrap();
        assert_eq!(state_bits(&st_a), state_bits(&st_b), "W={w}");

        // train: global-batch SGD semantics, deterministic run to run
        let run = || {
            let mut be = MockBackend::new();
            let mut st = SampleState::new(N);
            let mut pool = WorkerPool::new(&d, B);
            let mut sink = kakurenbo::engine::TrainSink::new(&mut st, 0);
            pool.run_data_parallel(&mut be, &d, &shards, StepMode::Train { lr: 0.03 }, &mut sink)
                .unwrap();
            (state_bits(&st), be.param.to_bits())
        };
        assert_eq!(run(), run(), "W={w}");
    }
}

// ---------------------------------------------------------------------------
// End-to-end through the real executor (skipped when artifacts are absent)
// ---------------------------------------------------------------------------

mod end_to_end {
    use kakurenbo::config::{presets, DatasetConfig, DpMode, StrategyConfig};
    use kakurenbo::coordinator::Trainer;
    use kakurenbo::data::shard::shard_order_aligned;
    use kakurenbo::engine::{DataParallel, StateExchange, StepMode, TrainSink, WorkerPool};
    use kakurenbo::metrics::RunResult;
    use kakurenbo::runtime::{default_artifacts_dir, ModelExecutor, XlaRuntime};
    use kakurenbo::state::SampleState;

    fn runtime() -> Option<XlaRuntime> {
        XlaRuntime::new(&default_artifacts_dir()).ok()
    }

    fn run(rt: &XlaRuntime, workers: usize, dp: DpMode) -> RunResult {
        let mut cfg = presets::by_name("cifar100_wrn").unwrap();
        cfg.epochs = 3;
        cfg.workers = workers;
        cfg.dp = dp;
        if let DatasetConfig::GaussMixture(ref mut c) = cfg.dataset {
            c.n_train = 512;
            c.n_val = 128;
        }
        cfg.strategy = StrategyConfig::kakurenbo(0.3);
        Trainer::new(rt, cfg).unwrap().run().unwrap()
    }

    /// Pooled execution through the PJRT executor is reproducible bit for
    /// bit: thread scheduling must never leak into recorded stats.
    #[test]
    fn pooled_trainer_is_reproducible() {
        let Some(rt) = runtime() else { return };
        for workers in [2usize, 4] {
            let a = run(&rt, workers, DpMode::SerialEquivalent);
            let b = run(&rt, workers, DpMode::SerialEquivalent);
            assert_eq!(a.records.len(), b.records.len());
            for (x, y) in a.records.iter().zip(&b.records) {
                assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits());
                assert_eq!(x.val_acc.to_bits(), y.val_acc.to_bits());
                assert_eq!(x.hidden, y.hidden);
                assert_eq!(x.trained_samples, y.trained_samples);
                assert_eq!(x.worker_samples, y.worker_samples);
            }
        }
    }

    /// `--workers N --dp average` trains end-to-end through the real
    /// `ModelExecutor` path (per-lane PJRT replicas, no mock carve-out)
    /// and is bitwise reproducible across repeated runs at fixed seed/N.
    #[test]
    fn dp_average_trainer_is_reproducible() {
        let Some(rt) = runtime() else { return };
        for workers in [2usize, 4] {
            let a = run(&rt, workers, DpMode::Average);
            let b = run(&rt, workers, DpMode::Average);
            assert_eq!(a.records.len(), b.records.len());
            for (x, y) in a.records.iter().zip(&b.records) {
                assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits());
                assert_eq!(x.val_acc.to_bits(), y.val_acc.to_bits());
                assert_eq!(x.hidden, y.hidden);
                assert_eq!(x.trained_samples, y.trained_samples);
                assert_eq!(x.worker_samples, y.worker_samples);
                assert_eq!(x.dp_syncs, y.dp_syncs);
            }
            // the averaging schedule actually averaged: one sync per
            // global step of every trained epoch
            assert!(a.records.iter().all(|r| r.dp_syncs > 0));
        }
    }

    /// The averaging determinism contract on the real executor: when both
    /// workers see identical batches, per-step gradients are identical,
    /// so the W=2 average must match the single-replica run bit for bit.
    #[test]
    fn dp_average_identical_shards_match_single_replica() {
        let Some(rt) = runtime() else { return };
        let mut cfg = presets::by_name("cifar100_wrn").unwrap();
        let DatasetConfig::GaussMixture(ref mut c) = cfg.dataset else { unreachable!() };
        c.n_train = 256;
        c.n_val = 16;
        let data = cfg.dataset.generate(11);
        let b = 64;

        let half: Vec<u32> = (0..128).collect();
        let doubled: Vec<u32> = half.iter().chain(half.iter()).copied().collect();
        let shards2 = shard_order_aligned(&doubled, 2, b);
        assert_eq!(shards2[0].indices, shards2[1].indices);
        let shards1 = shard_order_aligned(&half, 1, b);

        let run = |shards: &[kakurenbo::data::shard::Shard]| {
            let mut exec = ModelExecutor::new(&rt, "mlp_c100_b64", 5).unwrap();
            let mut pool = WorkerPool::new(&data.train, b);
            let mut state = SampleState::new(data.train.n);
            let mut sink = TrainSink::new(&mut state, 0);
            pool.run_data_parallel(
                &mut exec,
                &data.train,
                shards,
                StepMode::Train { lr: 0.05 },
                &mut sink,
            )
            .unwrap();
            exec.export_state()
                .unwrap()
                .iter()
                .flatten()
                .map(|v| v.to_bits())
                .collect::<Vec<u32>>()
        };
        assert_eq!(run(&shards2), run(&shards1));
    }

    /// Averaged parameters round-trip through the checkpoint layer: a
    /// save/load cycle after a `--dp average` pass restores every bit.
    #[test]
    fn dp_average_checkpoint_roundtrip() {
        let Some(rt) = runtime() else { return };
        let mut cfg = presets::by_name("cifar100_wrn").unwrap();
        let DatasetConfig::GaussMixture(ref mut c) = cfg.dataset else { unreachable!() };
        c.n_train = 256;
        c.n_val = 16;
        let data = cfg.dataset.generate(13);
        let b = 64;
        let order: Vec<u32> = (0..256).collect();
        let shards = shard_order_aligned(&order, 2, b);

        let mut exec = ModelExecutor::new(&rt, "mlp_c100_b64", 7).unwrap();
        let mut pool = WorkerPool::new(&data.train, b);
        let mut state = SampleState::new(data.train.n);
        let mut sink = TrainSink::new(&mut state, 0);
        pool.run_data_parallel(
            &mut exec,
            &data.train,
            &shards,
            StepMode::Train { lr: 0.05 },
            &mut sink,
        )
        .unwrap();

        let dir =
            std::env::temp_dir().join(format!("kakurenbo_dp_ckpt_{}", std::process::id()));
        kakurenbo::runtime::checkpoint::save(&exec, &dir, 0).unwrap();
        let mut restored = ModelExecutor::new(&rt, "mlp_c100_b64", 999).unwrap();
        let epoch = kakurenbo::runtime::checkpoint::load(&mut restored, &dir).unwrap();
        assert_eq!(epoch, 0);
        let pa = exec.export_named_params().unwrap();
        let pb = restored.export_named_params().unwrap();
        for ((n1, d1), (n2, d2)) in pa.iter().zip(&pb) {
            assert_eq!(n1, n2);
            let ba: Vec<u32> = d1.iter().map(|v| v.to_bits()).collect();
            let bb: Vec<u32> = d2.iter().map(|v| v.to_bits()).collect();
            assert_eq!(ba, bb, "leaf {n1} diverged through the checkpoint");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Replication (via the `Send` replica builder) and the export/import
    /// round-trip preserve every parameter bit (the pool's replica
    /// contract) — including across a real thread boundary.
    #[test]
    fn executor_replication_is_exact() {
        let Some(rt) = runtime() else { return };
        let mut exec = ModelExecutor::new(&rt, "cnn_c32_b64", 3).unwrap();
        let b = exec.meta.batch;
        let x = vec![0.2f32; b * exec.meta.sample_dim()];
        let y = vec![1i32; b * exec.meta.label_len()];
        let sw = vec![1.0f32; b];
        exec.train_step(&x, &y, &sw, 0.05).unwrap(); // move off the init point
        let a = exec.export_state().unwrap();

        // builder crosses a thread; the replica is built *on* that thread
        let builder = exec.replica_builder().unwrap();
        let bb = std::thread::spawn(move || {
            let replica = builder().unwrap();
            replica.export_state().unwrap()
        })
        .join()
        .unwrap();
        assert_eq!(a.len(), bb.len());
        for (la, lb) in a.iter().zip(&bb) {
            let ba: Vec<u32> = la.iter().map(|v| v.to_bits()).collect();
            let bbits: Vec<u32> = lb.iter().map(|v| v.to_bits()).collect();
            assert_eq!(ba, bbits);
        }
        // import back and verify the forward pass is bit-identical
        let mut other = ModelExecutor::new(&rt, "cnn_c32_b64", 999).unwrap();
        other.import_state(&a).unwrap();
        let s1 = exec.fwd_stats(&x, &y).unwrap();
        let s2 = other.fwd_stats(&x, &y).unwrap();
        let bits = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&s1.loss), bits(&s2.loss));
        assert_eq!(bits(&s1.conf), bits(&s2.conf));
    }
}
