"""AOT pipeline invariants: manifest consistency and HLO-text round-trip.

These tests pin the Python->Rust contract: every variant registered in
compile.model.VARIANTS must lower, carry a faithful manifest entry, and
emit HLO text that XLA's own parser accepts (the same parser the Rust
runtime uses via HloModuleProto::from_text_file).
"""

import json
import math
import os

import jax
import pytest

from compile import aot
from compile import model as M

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as f:
        return json.load(f)


def test_manifest_covers_all_variants():
    m = manifest()
    for name, spec in M.VARIANTS.items():
        assert name in m["models"], f"{name} missing from manifest"
        vm = m["models"][name]
        assert vm["batch"] == spec.batch
        assert vm["classes"] == spec.classes
        assert vm["param_count"] == spec.param_count
        assert tuple(vm["input_shape"]) == spec.input_shape
        # param order must match the spec exactly (Rust threads by position)
        assert [p["name"] for p in vm["params"]] == [p.name for p in spec.param_specs]
        for pj, ps in zip(vm["params"], spec.param_specs):
            assert tuple(pj["shape"]) == ps.shape
            assert math.isclose(pj["init_std"], ps.init_std, rel_tol=1e-9)


def test_artifact_files_exist_and_are_hlo_text():
    m = manifest()
    for name, vm in m["models"].items():
        for kind, fname in vm["artifacts"].items():
            path = os.path.join(ART, fname)
            assert os.path.exists(path), f"{fname} missing"
            head = open(path).read(200)
            assert head.startswith("HloModule"), f"{fname} is not HLO text"


def test_fingerprint_matches_current_sources():
    m = manifest()
    assert m["fingerprint"] == aot.source_fingerprint(), (
        "artifacts are stale: run `make artifacts`"
    )


def test_lowering_is_deterministic():
    """Lowering the same variant twice yields identical HLO text."""
    spec = M.VARIANTS["mlp_c10_b64"]
    fn = M.build_fwd_stats(spec)
    args = M.example_args(spec, "fwd_stats")
    t1 = aot.to_hlo_text(jax.jit(fn).lower(*args))
    t2 = aot.to_hlo_text(jax.jit(fn).lower(*args))
    assert t1 == t2


def test_train_step_artifact_signature():
    """Entry computation must take 2P+5 parameters and return 2P+3 values."""
    m = manifest()
    vm = m["models"]["mlp_c10_b64"]
    path = os.path.join(ART, vm["artifacts"]["train_step"])
    text = open(path).read()
    n = len(vm["params"])
    # parameter count: count 'parameter(k)' occurrences in the entry
    import re
    params = set(re.findall(r"parameter\((\d+)\)", text))
    assert len(params) == 2 * n + 5, f"found {len(params)} entry parameters"
