"""L2 correctness: kernel-backed model steps vs pure-jnp reference steps.

The `use_ref=True` path builds the identical computation from ref.py
oracles; agreement here means the exact HLO we ship to Rust is equivalent
to textbook SGD-with-momentum training over these models.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

SMALL = {
    "mlp": M.mlp_spec("t_mlp", 16, 12, 24, 7),
    "cnn": M.cnn_spec("t_cnn", 8, 8, 3, 4, 8, 16, 5),
    "segnet": M.segnet_spec("t_seg", 4, 8, 3, 6, 2),
}


def init_params(spec, seed=0):
    key = jax.random.PRNGKey(seed)
    leaves = []
    for ps in spec.param_specs:
        key, sub = jax.random.split(key)
        if ps.init_std == 0.0:
            leaves.append(jnp.zeros(ps.shape, jnp.float32))
        else:
            leaves.append(jax.random.normal(sub, ps.shape) * ps.init_std)
    return leaves


def rand_batch(spec, seed=1):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, (spec.batch, *spec.input_shape), jnp.float32)
    y = jax.random.randint(k2, (spec.batch, *spec.label_shape), 0, spec.classes)
    return x, y


@pytest.mark.parametrize("fam", ["mlp", "cnn", "segnet"])
def test_train_step_kernel_vs_ref(fam):
    spec = SMALL[fam]
    p = init_params(spec)
    v = [jnp.zeros_like(l) for l in p]
    x, y = rand_batch(spec)
    sw = jnp.ones((spec.batch,), jnp.float32)
    args = (*p, *v, x, y, sw, jnp.float32(0.05), jnp.float32(0.9))

    out_k = M.build_train_step(spec, use_ref=False)(*args)
    out_r = M.build_train_step(spec, use_ref=True)(*args)
    assert len(out_k) == 2 * len(spec.param_specs) + 3
    for a, b in zip(out_k, out_r):
        np.testing.assert_allclose(a, b, rtol=2e-3, atol=1e-4)


@pytest.mark.parametrize("fam", ["mlp", "cnn", "segnet"])
def test_fwd_stats_kernel_vs_ref(fam):
    spec = SMALL[fam]
    p = init_params(spec)
    x, y = rand_batch(spec)
    out_k = M.build_fwd_stats(spec, use_ref=False)(*p, x, y)
    out_r = M.build_fwd_stats(spec, use_ref=True)(*p, x, y)
    for a, b in zip(out_k, out_r):
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-4)


@pytest.mark.parametrize("fam", ["mlp", "cnn"])
def test_fwd_embed_shapes(fam):
    spec = SMALL[fam]
    p = init_params(spec)
    x, y = rand_batch(spec)
    loss, correct, conf, emb, probs = M.build_fwd_embed(spec)(*p, x, y)
    assert emb.shape == (spec.batch, spec.embed_dim)
    assert probs.shape == (spec.batch, spec.classes)
    np.testing.assert_allclose(np.sum(np.asarray(probs), axis=-1), 1.0, rtol=1e-4)


def test_training_reduces_loss_mlp():
    """A few hundred steps of the shipped train_step must actually learn."""
    spec = M.mlp_spec("t_learn", 32, 8, 32, 4)
    step = jax.jit(M.build_train_step(spec, use_ref=True))
    p = init_params(spec, seed=3)
    v = [jnp.zeros_like(l) for l in p]
    key = jax.random.PRNGKey(0)
    # linearly separable synthetic task
    centers = jax.random.normal(jax.random.PRNGKey(9), (4, 8)) * 2.0
    sw = jnp.ones((32,), jnp.float32)
    first = last = None
    for i in range(150):
        key, k1, k2 = jax.random.split(key, 3)
        y = jax.random.randint(k1, (32,), 0, 4)
        x = centers[y] + 0.3 * jax.random.normal(k2, (32, 8))
        out = step(*p, *v, x, y, sw, jnp.float32(0.1), jnp.float32(0.9))
        n = len(spec.param_specs)
        p, v = list(out[:n]), list(out[n:2 * n])
        loss = float(jnp.mean(out[2 * n]))
        if first is None:
            first = loss
        last = loss
    assert last < first * 0.3, (first, last)


def test_sample_weight_zero_freezes_update():
    """sw=0 for all samples => gradient is exactly zero => params unchanged."""
    spec = SMALL["mlp"]
    p = init_params(spec)
    v = [jnp.zeros_like(l) for l in p]
    x, y = rand_batch(spec)
    sw = jnp.zeros((spec.batch,), jnp.float32)
    out = M.build_train_step(spec, use_ref=True)(
        *p, *v, x, y, sw, jnp.float32(0.5), jnp.float32(0.9)
    )
    n = len(spec.param_specs)
    for before, after in zip(p, out[:n]):
        np.testing.assert_allclose(before, after, atol=1e-7)


def test_sample_weight_scales_gradient():
    """Doubling every sw doubles the step taken from zero velocity."""
    spec = SMALL["mlp"]
    p = init_params(spec)
    v = [jnp.zeros_like(l) for l in p]
    x, y = rand_batch(spec)
    n = len(spec.param_specs)
    step = M.build_train_step(spec, use_ref=True)
    one = step(*p, *v, x, y, jnp.ones((spec.batch,)), jnp.float32(0.1), jnp.float32(0.0))
    two = step(*p, *v, x, y, 2 * jnp.ones((spec.batch,)), jnp.float32(0.1), jnp.float32(0.0))
    for p0, p1, p2 in zip(p, one[:n], two[:n]):
        np.testing.assert_allclose(
            np.asarray(p2 - p0), 2 * np.asarray(p1 - p0), rtol=1e-3, atol=1e-6
        )


def test_segnet_stats_semantics():
    """segnet PA is thresholded mean pixel accuracy; conf is mean pixel conf."""
    spec = SMALL["segnet"]
    p = init_params(spec)
    x, y = rand_batch(spec)
    loss, correct, conf = M.build_fwd_stats(spec, use_ref=True)(*p, x, y)
    assert loss.shape == (spec.batch,)
    assert set(np.unique(np.asarray(correct))) <= {0.0, 1.0}
    assert np.all((np.asarray(conf) > 0) & (np.asarray(conf) <= 1 + 1e-6))


def test_param_specs_manifest_consistency():
    for name, spec in M.VARIANTS.items():
        assert spec.name == name
        count = sum(int(math.prod(p.shape)) for p in spec.param_specs)
        assert count == spec.param_count
        # names unique and ordered deterministically
        names = [p.name for p in spec.param_specs]
        assert len(set(names)) == len(names)
        if spec.family != "segnet":
            assert spec.embed_dim > 0
