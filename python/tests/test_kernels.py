"""L1 correctness: every Pallas kernel vs its pure-jnp oracle in ref.py.

hypothesis sweeps shapes, magnitudes, and seeds; the oracle comparison is
the core correctness signal for the whole stack (the lowered HLO contains
exactly these kernels).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.fused_loss_stats import fused_loss_stats
from compile.kernels.matmul_bias_act import matmul_bias_act, pl_matmul
from compile.kernels.sgd_momentum import sgd_momentum, sgd_momentum_tree

RTOL, ATOL = 1e-4, 1e-5


def _rand(key, shape, scale=1.0):
    return jax.random.normal(key, shape, dtype=jnp.float32) * scale


# ---------------------------------------------------------------------------
# fused_loss_stats
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 130),
    c=st.integers(2, 64),
    scale=st.sampled_from([0.1, 1.0, 10.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_loss_stats_matches_ref(b, c, scale, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    z = _rand(k1, (b, c), scale)
    y = jax.random.randint(k2, (b,), 0, c)
    loss, correct, conf = fused_loss_stats(z, y)
    rl, rc, rp = ref.fused_loss_stats(z, y)
    np.testing.assert_allclose(loss, rl, rtol=RTOL, atol=ATOL)
    np.testing.assert_array_equal(np.asarray(correct), np.asarray(rc))
    np.testing.assert_allclose(conf, rp, rtol=RTOL, atol=ATOL)


@settings(max_examples=10, deadline=None)
@given(b=st.integers(2, 64), c=st.integers(2, 32), seed=st.integers(0, 999))
def test_loss_stats_grad_matches_ref(b, c, seed):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    z = _rand(k1, (b, c), 3.0)
    y = jax.random.randint(k2, (b,), 0, c)
    dl = _rand(k3, (b,))

    g1 = jax.grad(lambda z: jnp.sum(fused_loss_stats(z, y)[0] * dl))(z)
    g2 = ref.fused_loss_stats_grad(z, y, dl)
    np.testing.assert_allclose(g1, g2, rtol=RTOL, atol=ATOL)


def test_loss_stats_invariants():
    """conf in (0,1]; loss >= -log(conf_of_label); correct in {0,1}."""
    k = jax.random.PRNGKey(7)
    z = _rand(k, (128, 10), 5.0)
    y = jax.random.randint(k, (128,), 0, 10)
    loss, correct, conf = fused_loss_stats(z, y)
    assert np.all(np.asarray(conf) > 0) and np.all(np.asarray(conf) <= 1 + 1e-6)
    assert np.all(np.asarray(loss) >= -1e-5)
    assert set(np.unique(np.asarray(correct))) <= {0.0, 1.0}
    # a correct prediction with confidence p has loss = -log(p) exactly
    li = np.asarray(loss)[np.asarray(correct) == 1.0]
    ci = np.asarray(conf)[np.asarray(correct) == 1.0]
    np.testing.assert_allclose(li, -np.log(ci), rtol=1e-4, atol=1e-5)


def test_loss_stats_extreme_logits_stable():
    z = jnp.array([[1e4, -1e4, 0.0], [-1e4, 1e4, 0.0]], jnp.float32)
    y = jnp.array([0, 0], jnp.int32)
    loss, correct, conf = fused_loss_stats(z, y)
    assert np.all(np.isfinite(np.asarray(loss)))
    np.testing.assert_allclose(np.asarray(correct), [1.0, 0.0])
    np.testing.assert_allclose(np.asarray(conf), [1.0, 1.0], rtol=1e-5)


# ---------------------------------------------------------------------------
# matmul_bias_act
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 160),
    k=st.integers(1, 160),
    n=st.integers(1, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_pl_matmul_matches_ref(m, k, n, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    a = _rand(k1, (m, k))
    b = _rand(k2, (k, n))
    np.testing.assert_allclose(
        pl_matmul(a, b), ref.matmul(a, b), rtol=1e-3, atol=1e-4
    )


@pytest.mark.parametrize("act", ["relu", "id"])
@pytest.mark.parametrize("shape", [(4, 8, 16), (64, 64, 100), (33, 70, 20), (128, 128, 128)])
def test_matmul_bias_act_matches_ref(act, shape):
    m, k, n = shape
    keys = jax.random.split(jax.random.PRNGKey(m * 1000 + n), 3)
    x, w = _rand(keys[0], (m, k)), _rand(keys[1], (k, n))
    b = _rand(keys[2], (n,))
    np.testing.assert_allclose(
        matmul_bias_act(x, w, b, act),
        ref.matmul_bias_act(x, w, b, act),
        rtol=1e-3,
        atol=1e-4,
    )


@pytest.mark.parametrize("act", ["relu", "id"])
def test_matmul_bias_act_grads(act):
    keys = jax.random.split(jax.random.PRNGKey(3), 4)
    x, w = _rand(keys[0], (32, 48)), _rand(keys[1], (48, 24))
    b, co = _rand(keys[2], (24,)), _rand(keys[3], (32, 24))

    def f_pl(x, w, b):
        return jnp.sum(matmul_bias_act(x, w, b, act) * co)

    def f_ref(x, w, b):
        return jnp.sum(ref.matmul_bias_act(x, w, b, act) * co)

    g1 = jax.grad(f_pl, argnums=(0, 1, 2))(x, w, b)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
    for a, r in zip(g1, g2):
        np.testing.assert_allclose(a, r, rtol=1e-3, atol=1e-4)


# ---------------------------------------------------------------------------
# sgd_momentum
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 20000),
    lr=st.floats(1e-4, 1.0),
    mu=st.floats(0.0, 0.99),
    seed=st.integers(0, 2**31 - 1),
)
def test_sgd_momentum_matches_ref(n, lr, mu, seed):
    keys = jax.random.split(jax.random.PRNGKey(seed), 3)
    w, v, g = _rand(keys[0], (n,)), _rand(keys[1], (n,)), _rand(keys[2], (n,))
    w1, v1 = sgd_momentum(w, v, g, lr, mu)
    w2, v2 = ref.sgd_momentum(w, v, g, jnp.float32(lr), jnp.float32(mu))
    np.testing.assert_allclose(w1, w2, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(v1, v2, rtol=1e-5, atol=1e-6)


def test_sgd_momentum_nd_shapes():
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    for shape in [(3, 3, 3, 16), (64, 128), (7,), (1, 1, 16, 2)]:
        w, v, g = (_rand(k, shape) for k in keys)
        w1, v1 = sgd_momentum(w, v, g, 0.05, 0.9)
        w2, v2 = ref.sgd_momentum(w, v, g, 0.05, 0.9)
        np.testing.assert_allclose(w1, w2, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(v1, v2, rtol=1e-5, atol=1e-6)
        assert w1.shape == shape and v1.shape == shape


def test_sgd_momentum_tree():
    params = {"a/w": jnp.ones((4, 4)), "a/b": jnp.zeros((4,))}
    vel = {k: jnp.zeros_like(x) for k, x in params.items()}
    grads = {k: jnp.ones_like(x) for k, x in params.items()}
    p1, v1 = sgd_momentum_tree(params, vel, grads, 0.1, 0.9)
    np.testing.assert_allclose(p1["a/w"], 0.9 * np.ones((4, 4)), rtol=1e-6)
    np.testing.assert_allclose(v1["a/b"], np.ones((4,)), rtol=1e-6)
    # two steps accumulate momentum: v2 = 0.9*1 + 1 = 1.9
    p2, v2 = sgd_momentum_tree(p1, v1, grads, 0.1, 0.9)
    np.testing.assert_allclose(v2["a/w"], 1.9 * np.ones((4, 4)), rtol=1e-6)
