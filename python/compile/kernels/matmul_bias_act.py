"""Pallas kernel: tiled fused dense layer  act(x @ w + b).

The classifier head / MLP trunk matmuls of the models in this repo run
through this kernel so the L2 graph exercises a real tiled MXU schedule:

  grid = (M/bm, N/bn, K/bk); each step accumulates one (bm, bk)x(bk, bn)
  partial product into a VMEM accumulator; on the last K step the bias add
  and activation are fused into the epilogue (no second pass over the
  output tile).

On real TPU the natural tile is (128, 128) f32 / bf16 for the 128x128
systolic MXU; under interpret=True the tile sizes only shape the HLO, so
we clamp them to the problem size.  The custom VJP expresses dx / dw as
two more tiled matmuls through the same kernel (dimension-swapped), with
the activation mask applied by a small elementwise Pallas kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_M = 128
TILE_N = 128
TILE_K = 128


def _block(n: int, cap: int) -> int:
    """Largest power-of-two divisor of n that is <= cap; n itself otherwise.

    Blocks must divide the dimension exactly: interpret-mode Pallas pads
    out-of-bounds reads with NaN, which would poison the K-accumulation.
    """
    best = n
    t = 1
    while t * 2 <= min(n, cap):
        t *= 2
        if n % t == 0:
            best = t
    return best if best <= cap else n


def _mm_kernel(x_ref, w_ref, o_ref):
    """Grid (i, j, k): accumulate x[i,k] @ w[k,j] into the revisited o tile.

    The output BlockSpec maps every k step of a given (i, j) to the same
    tile, so o_ref acts as the VMEM accumulator (standard Pallas pattern);
    no scratch buffer and no extra HBM traffic for partials.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def pl_matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    """Tiled Pallas matmul f32[M,K] @ f32[K,N] -> f32[M,N]."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    bm, bn, bk = _block(m, TILE_M), _block(n, TILE_N), _block(k, TILE_K)
    nk = pl.cdiv(k, bk)
    return pl.pallas_call(
        _mm_kernel,
        grid=(pl.cdiv(m, bm), pl.cdiv(n, bn), nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w)


def _bias_act_kernel(y_ref, b_ref, o_ref, *, act: str):
    y = y_ref[...] + b_ref[...][None, :]
    if act == "relu":
        y = jnp.maximum(y, 0.0)
    o_ref[...] = y


def _bias_act(y: jax.Array, b: jax.Array, act: str) -> jax.Array:
    m, n = y.shape
    bm = _block(m, TILE_M)
    return pl.pallas_call(
        functools.partial(_bias_act_kernel, act=act),
        grid=(pl.cdiv(m, bm),),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(y, b)


def _mask_kernel(dy_ref, out_ref, mask_ref):
    """mask = dy * (out > 0) — relu backward."""
    mask_ref[...] = dy_ref[...] * (out_ref[...] > 0.0).astype(jnp.float32)


def _relu_mask(dy: jax.Array, out: jax.Array) -> jax.Array:
    m, n = dy.shape
    bm = _block(m, TILE_M)
    return pl.pallas_call(
        _mask_kernel,
        grid=(pl.cdiv(m, bm),),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(dy, out)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def matmul_bias_act(x, w, b, act: str = "relu"):
    """Fused dense layer: act(x @ w + b); act in {"relu", "id"}."""
    return _bias_act(pl_matmul(x, w), b, act)


def _mba_fwd(x, w, b, act):
    out = _bias_act(pl_matmul(x, w), b, act)
    return out, (x, w, out)


def _mba_bwd(act, res, dy):
    x, w, out = res
    if act == "relu":
        dy = _relu_mask(dy, out)
    dx = pl_matmul(dy, w.T)
    dw = pl_matmul(x.T, dy)
    db = jnp.sum(dy, axis=0)
    return dx, dw, db


matmul_bias_act.defvjp(_mba_fwd, _mba_bwd)
