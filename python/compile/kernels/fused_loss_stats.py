"""Pallas kernel: fused per-sample loss / prediction-accuracy / confidence.

This is KAKURENBO's L1 hot-spot.  The hiding decision (paper §3.1) needs,
for *every* sample on *every* epoch:

  * the softmax cross-entropy loss          (sorting key for hiding),
  * whether the prediction is correct (PA)  (move-back rule),
  * the max softmax probability (PC)        (move-back rule, threshold τ).

A naive implementation makes three passes over `logits[B, C]` (softmax,
argmax, gather).  This kernel computes all three statistics in a single
pass over each VMEM-resident block of rows, so on a real TPU the logits are
read from HBM exactly once.  The backward pass (only `loss` is
differentiable) is a second Pallas kernel that recomputes the row softmax
in-register instead of saving it (rematerialization: saves B*C*4 bytes of
residual memory per step for one extra exp).

Lowered with interpret=True so the emitted HLO runs on any PJRT backend
(see /opt/xla-example/README.md); TPU perf is estimated in DESIGN.md §7.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows handled per grid step.  C (classes) is always materialized fully so
# the row reduction is single-pass; block VMEM = BLOCK_B * C * 4 bytes
# (64 * 1024 * 4 = 256 KiB at C=1024 — comfortably inside a 16 MiB VMEM).
BLOCK_B = 64


def _block_rows(b: int) -> int:
    """Largest power-of-two divisor of b <= BLOCK_B; b itself otherwise.

    Row blocks must divide the batch exactly: interpret-mode Pallas pads
    out-of-bounds reads with NaN.
    """
    best = b
    t = 1
    while t * 2 <= min(b, BLOCK_B):
        t *= 2
        if b % t == 0:
            best = t
    return best if best <= BLOCK_B else b


def _fwd_kernel(z_ref, y_ref, loss_ref, correct_ref, conf_ref, *, n_classes):
    """One block of rows: single pass -> (loss, correct, conf)."""
    z = z_ref[...].astype(jnp.float32)       # (bb, C)
    y = y_ref[...]                            # (bb,) int32
    m = jnp.max(z, axis=-1)                   # row max
    e = jnp.exp(z - m[:, None])
    s = jnp.sum(e, axis=-1)
    lse = m + jnp.log(s)
    # Gather-free label logit: one-hot contraction vectorizes on the VPU.
    cols = jax.lax.broadcasted_iota(jnp.int32, z.shape, 1)
    onehot = cols == y[:, None]
    zy = jnp.sum(jnp.where(onehot, z, 0.0), axis=-1)
    pred = jnp.argmax(z, axis=-1).astype(jnp.int32)
    loss_ref[...] = lse - zy
    correct_ref[...] = (pred == y).astype(jnp.float32)
    conf_ref[...] = jnp.exp(m - lse)          # = max softmax prob


def _bwd_kernel(z_ref, y_ref, dloss_ref, dz_ref):
    """dz = (softmax(z) - onehot(y)) * dloss[:, None], softmax recomputed."""
    z = z_ref[...].astype(jnp.float32)
    y = y_ref[...]
    dloss = dloss_ref[...]
    m = jnp.max(z, axis=-1)
    e = jnp.exp(z - m[:, None])
    p = e / jnp.sum(e, axis=-1)[:, None]
    cols = jax.lax.broadcasted_iota(jnp.int32, z.shape, 1)
    onehot = (cols == y[:, None]).astype(jnp.float32)
    dz_ref[...] = (p - onehot) * dloss[:, None]


def _fwd_call(logits, labels):
    b, c = logits.shape
    bb = _block_rows(b)
    grid = (pl.cdiv(b, bb),)
    out_shapes = [jax.ShapeDtypeStruct((b,), jnp.float32)] * 3
    return pl.pallas_call(
        functools.partial(_fwd_kernel, n_classes=c),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, c), lambda i: (i, 0)),
            pl.BlockSpec((bb,), lambda i: (i,)),
        ],
        out_specs=[pl.BlockSpec((bb,), lambda i: (i,))] * 3,
        out_shape=out_shapes,
        interpret=True,
    )(logits, labels)


def _bwd_call(logits, labels, dloss):
    b, c = logits.shape
    bb = _block_rows(b)
    grid = (pl.cdiv(b, bb),)
    return pl.pallas_call(
        _bwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, c), lambda i: (i, 0)),
            pl.BlockSpec((bb,), lambda i: (i,)),
            pl.BlockSpec((bb,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bb, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, c), jnp.float32),
        interpret=True,
    )(logits, labels, dloss)


@jax.custom_vjp
def fused_loss_stats(logits, labels):
    """Per-sample (loss, correct, conf) from logits[B,C] and labels[B]i32."""
    loss, correct, conf = _fwd_call(logits, labels)
    return loss, correct, conf


def _vjp_fwd(logits, labels):
    out = _fwd_call(logits, labels)
    return out, (logits, labels)


def _vjp_bwd(res, cotangents):
    logits, labels = res
    dloss, _dcorrect, _dconf = cotangents  # correct/conf: non-differentiable
    dz = _bwd_call(logits, labels, dloss)
    return dz, None


fused_loss_stats.defvjp(_vjp_fwd, _vjp_bwd)
