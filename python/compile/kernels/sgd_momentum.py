"""Pallas kernel: fused heavy-ball SGD parameter update.

    v' = mu * v + g
    w' = w  - lr * v'

Applied leaf-by-leaf to the parameter pytree (each leaf flattened to 1-D
and processed in VMEM-sized tiles).  Fusing the two element-wise ops means
w, v, g stream through VMEM exactly once per step instead of twice.

KAKURENBO's learning-rate rule (paper Eq. 8, eta_e = eta_base/(1-F_e)) is
applied by the Rust coordinator: `lr` arrives as a runtime scalar argument
of the lowered train_step, so one compiled artifact serves every hiding
fraction and every LR schedule.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 4096  # elements per grid step; 3 operands * 4 B * 4096 = 48 KiB VMEM


def _block_elems(n: int) -> int:
    """Largest power-of-two divisor of n <= BLOCK; n itself otherwise.

    Blocks must divide n exactly: interpret-mode Pallas pads out-of-bounds
    reads with NaN (harmless for writes but kept exact for hygiene).
    """
    best = n
    t = 1
    while t * 2 <= min(n, BLOCK):
        t *= 2
        if n % t == 0:
            best = t
    return best if best <= BLOCK else n


def _update_kernel(w_ref, v_ref, g_ref, lr_ref, mu_ref, w_out_ref, v_out_ref):
    lr = lr_ref[0]
    mu = mu_ref[0]
    v_new = mu * v_ref[...] + g_ref[...]
    v_out_ref[...] = v_new
    w_out_ref[...] = w_ref[...] - lr * v_new


def _update_flat(w: jax.Array, v: jax.Array, g: jax.Array, lr: jax.Array, mu: jax.Array):
    n = w.shape[0]
    bn = _block_elems(n)
    grid = (pl.cdiv(n, bn),)
    return pl.pallas_call(
        _update_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=True,
    )(w, v, g, lr, mu)


def sgd_momentum(w: jax.Array, v: jax.Array, g: jax.Array, lr, mu):
    """Fused momentum update of one parameter leaf (any shape)."""
    shape = w.shape
    lr1 = jnp.reshape(jnp.asarray(lr, jnp.float32), (1,))
    mu1 = jnp.reshape(jnp.asarray(mu, jnp.float32), (1,))
    w_new, v_new = _update_flat(
        w.reshape(-1), v.reshape(-1), g.reshape(-1), lr1, mu1
    )
    return w_new.reshape(shape), v_new.reshape(shape)


def sgd_momentum_tree(params, velocity, grads, lr, mu):
    """Apply the fused update across a parameter pytree."""
    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_v = treedef.flatten_up_to(velocity)
    flat_g = treedef.flatten_up_to(grads)
    new_p, new_v = [], []
    for p, v, g in zip(flat_p, flat_v, flat_g):
        np_, nv_ = sgd_momentum(p, v, g, lr, mu)
        new_p.append(np_)
        new_v.append(nv_)
    return (
        jax.tree_util.tree_unflatten(treedef, new_p),
        jax.tree_util.tree_unflatten(treedef, new_v),
    )
