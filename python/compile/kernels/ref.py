"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this package
is pytest-compared (to tight fp tolerance) against the function of the same
name here.  They are also used by `model.py --ref` to build a kernel-free
version of the full train step for end-to-end L2 checks.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def fused_loss_stats(logits: jax.Array, labels: jax.Array):
    """Per-sample softmax cross-entropy loss + prediction stats.

    Args:
      logits: f32[B, C]
      labels: i32[B]

    Returns:
      loss:    f32[B]  -- softmax cross-entropy per sample
      correct: f32[B]  -- 1.0 where argmax(logits) == label (PA in the paper)
      conf:    f32[B]  -- max softmax probability (PC in the paper)
    """
    z = logits.astype(jnp.float32)
    m = jnp.max(z, axis=-1)
    lse = m + jnp.log(jnp.sum(jnp.exp(z - m[:, None]), axis=-1))
    zy = jnp.take_along_axis(z, labels[:, None].astype(jnp.int32), axis=-1)[:, 0]
    loss = lse - zy
    pred = jnp.argmax(z, axis=-1)
    correct = (pred == labels).astype(jnp.float32)
    conf = jnp.exp(m - lse)
    return loss, correct, conf


def fused_loss_stats_grad(logits: jax.Array, labels: jax.Array, dloss: jax.Array):
    """VJP of the `loss` output of fused_loss_stats w.r.t. logits.

    d logits = (softmax(z) - onehot(y)) * dloss[:, None]
    (`correct` and `conf` are non-differentiable outputs.)
    """
    z = logits.astype(jnp.float32)
    p = jax.nn.softmax(z, axis=-1)
    onehot = jax.nn.one_hot(labels, z.shape[-1], dtype=jnp.float32)
    return (p - onehot) * dloss[:, None]


def matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    """Plain f32 matmul oracle: f32[M,K] @ f32[K,N] -> f32[M,N]."""
    return jnp.matmul(a.astype(jnp.float32), b.astype(jnp.float32))


def matmul_bias_act(x: jax.Array, w: jax.Array, b: jax.Array, act: str) -> jax.Array:
    """Fused dense layer oracle: act(x @ w + b), act in {"relu", "id"}."""
    y = jnp.matmul(x, w) + b[None, :]
    if act == "relu":
        y = jnp.maximum(y, 0.0)
    elif act != "id":
        raise ValueError(f"unknown act {act!r}")
    return y


def sgd_momentum(w: jax.Array, v: jax.Array, g: jax.Array, lr, mu):
    """Heavy-ball SGD oracle: v' = mu*v + g ; w' = w - lr*v'."""
    v_new = mu * v + g
    w_new = w - lr * v_new
    return w_new, v_new
