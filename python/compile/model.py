"""L2: JAX model definitions + train/eval step builders (build-time only).

Three model families cover the paper's four workloads:

  * ``mlp``    — dense classifier; stand-in for WideResNet-28-10/CIFAR-100
                 and for the DeiT transfer-learning pipeline (Table 4).
  * ``cnn``    — small convnet on image tensors; stand-in for
                 ResNet-50 / EfficientNet-b3 on ImageNet-1K (proxy data).
  * ``segnet`` — per-pixel segmentation net; stand-in for DeepCAM.

All dense layers run through the Pallas ``matmul_bias_act`` kernel, the
loss/PA/PC epilogue through ``fused_loss_stats``, and the optimizer through
``sgd_momentum`` — so the lowered HLO contains the L1 kernels.  A
``use_ref=True`` switch builds the same computation from the pure-jnp
oracles, which pytest uses for end-to-end L2 equivalence checks.

Artifact calling convention (shared with rust/src/runtime/artifact.rs):

  train_step(params..., vel..., x, y, sw, lr, mu)
      -> (params'..., vel'..., loss[B], correct[B], conf[B])
  fwd_stats(params..., x, y) -> (loss[B], correct[B], conf[B])
  fwd_embed(params..., x, y) -> (loss, correct, conf, emb[B,D], probs[B,C])

Parameters are ordered by the ``param_specs`` list of each model spec; the
same order is recorded in artifacts/manifest.json which the Rust runtime
uses to initialize and thread buffers.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from .kernels import ref as kref
from .kernels.fused_loss_stats import fused_loss_stats
from .kernels.matmul_bias_act import matmul_bias_act
from .kernels.sgd_momentum import sgd_momentum_tree


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    name: str
    shape: tuple
    init_std: float  # 0.0 => zeros (biases)


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """A fully-shaped model variant (architecture + batch size)."""

    name: str            # e.g. "cnn_c32_b64"
    family: str          # mlp | cnn | segnet
    batch: int
    input_shape: tuple   # per-sample, e.g. (64,) or (8, 8, 3)
    label_shape: tuple   # per-sample label shape: () or (H, W)
    classes: int
    embed_dim: int       # penultimate feature dim (0 => no fwd_embed artifact)
    param_specs: tuple   # tuple[ParamSpec]
    arch: dict           # family-specific sizes

    @property
    def param_count(self) -> int:
        return sum(int(math.prod(p.shape)) for p in self.param_specs)


# ---------------------------------------------------------------------------
# Model specs
# ---------------------------------------------------------------------------


def _he(fan_in: int) -> float:
    return math.sqrt(2.0 / fan_in)


def _glorot(fan_in: int, fan_out: int) -> float:
    return math.sqrt(2.0 / (fan_in + fan_out))


def mlp_spec(name: str, batch: int, d_in: int, hidden: int, classes: int) -> ModelSpec:
    ps = (
        ParamSpec("fc1/w", (d_in, hidden), _he(d_in)),
        ParamSpec("fc1/b", (hidden,), 0.0),
        ParamSpec("fc2/w", (hidden, hidden), _he(hidden)),
        ParamSpec("fc2/b", (hidden,), 0.0),
        ParamSpec("head/w", (hidden, classes), _glorot(hidden, classes)),
        ParamSpec("head/b", (classes,), 0.0),
    )
    return ModelSpec(name, "mlp", batch, (d_in,), (), classes, hidden, ps,
                     {"d_in": d_in, "hidden": hidden})


def cnn_spec(name: str, batch: int, hw: int, c_in: int, ch1: int, ch2: int,
             hidden: int, classes: int) -> ModelSpec:
    ps = (
        ParamSpec("conv1/w", (3, 3, c_in, ch1), _he(9 * c_in)),
        ParamSpec("conv1/b", (ch1,), 0.0),
        ParamSpec("conv2/w", (3, 3, ch1, ch2), _he(9 * ch1)),
        ParamSpec("conv2/b", (ch2,), 0.0),
        ParamSpec("fc/w", (ch2, hidden), _he(ch2)),
        ParamSpec("fc/b", (hidden,), 0.0),
        ParamSpec("head/w", (hidden, classes), _glorot(hidden, classes)),
        ParamSpec("head/b", (classes,), 0.0),
    )
    return ModelSpec(name, "cnn", batch, (hw, hw, c_in), (), classes, hidden, ps,
                     {"hw": hw, "c_in": c_in, "ch1": ch1, "ch2": ch2, "hidden": hidden})


def segnet_spec(name: str, batch: int, hw: int, c_in: int, ch: int,
                classes: int) -> ModelSpec:
    ps = (
        ParamSpec("conv1/w", (3, 3, c_in, ch), _he(9 * c_in)),
        ParamSpec("conv1/b", (ch,), 0.0),
        ParamSpec("conv2/w", (3, 3, ch, ch), _he(9 * ch)),
        ParamSpec("conv2/b", (ch,), 0.0),
        ParamSpec("head/w", (1, 1, ch, classes), _glorot(ch, classes)),
        ParamSpec("head/b", (classes,), 0.0),
    )
    return ModelSpec(name, "segnet", batch, (hw, hw, c_in), (hw, hw), classes, 0, ps,
                     {"hw": hw, "c_in": c_in, "ch": ch})


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------


def _dense(use_ref: bool, x, w, b, act: str):
    if use_ref:
        return kref.matmul_bias_act(x, w, b, act)
    return matmul_bias_act(x, w, b, act)


def _conv(x, w, b):
    """3x3 (or 1x1) SAME conv, NHWC/HWIO, + bias."""
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + b[None, None, None, :]


def _loss_stats(use_ref: bool, logits, labels):
    if use_ref:
        return kref.fused_loss_stats(logits, labels)
    return fused_loss_stats(logits, labels)


def forward(spec: ModelSpec, params: dict, x, use_ref: bool = False):
    """Returns (logits, embed).  segnet: logits [B,H,W,C], embed None."""
    if spec.family == "mlp":
        h = _dense(use_ref, x, params["fc1/w"], params["fc1/b"], "relu")
        h = _dense(use_ref, h, params["fc2/w"], params["fc2/b"], "relu")
        logits = _dense(use_ref, h, params["head/w"], params["head/b"], "id")
        return logits, h
    if spec.family == "cnn":
        h = jax.nn.relu(_conv(x, params["conv1/w"], params["conv1/b"]))
        # 2x2 average pool
        h = jax.lax.reduce_window(
            h, 0.0, jax.lax.add, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
        ) / 4.0
        h = jax.nn.relu(_conv(h, params["conv2/w"], params["conv2/b"]))
        h = jnp.mean(h, axis=(1, 2))  # global average pool -> [B, ch2]
        h = _dense(use_ref, h, params["fc/w"], params["fc/b"], "relu")
        logits = _dense(use_ref, h, params["head/w"], params["head/b"], "id")
        return logits, h
    if spec.family == "segnet":
        h = jax.nn.relu(_conv(x, params["conv1/w"], params["conv1/b"]))
        h = jax.nn.relu(_conv(h, params["conv2/w"], params["conv2/b"]))
        logits = _conv(h, params["head/w"], params["head/b"])
        return logits, None
    raise ValueError(spec.family)


# Pixel-accuracy threshold above which a segmentation sample counts as
# "predicted correctly" (PA) — DeepCAM analogue of top-1 correctness.
SEG_PA_THRESHOLD = 0.90


def sample_stats(spec: ModelSpec, logits, y, use_ref: bool = False):
    """Per-sample (loss, correct, conf) for either task family."""
    if spec.family == "segnet":
        b = logits.shape[0]
        c = logits.shape[-1]
        flat_logits = logits.reshape(b, -1, c)
        flat_y = y.reshape(b, -1)
        npix = flat_y.shape[1]
        pl_, pc_, pf_ = _loss_stats(
            use_ref, flat_logits.reshape(-1, c), flat_y.reshape(-1)
        )
        loss = jnp.mean(pl_.reshape(b, npix), axis=1)
        pixacc = jnp.mean(pc_.reshape(b, npix), axis=1)
        conf = jnp.mean(pf_.reshape(b, npix), axis=1)
        correct = (pixacc > SEG_PA_THRESHOLD).astype(jnp.float32)
        return loss, correct, conf
    return _loss_stats(use_ref, logits, y)


# ---------------------------------------------------------------------------
# Step builders (the functions that get AOT-lowered)
# ---------------------------------------------------------------------------


def params_dict(spec: ModelSpec, leaves: Sequence[jax.Array]) -> dict:
    assert len(leaves) == len(spec.param_specs)
    return {p.name: l for p, l in zip(spec.param_specs, leaves)}


def params_leaves(spec: ModelSpec, d: dict) -> list:
    return [d[p.name] for p in spec.param_specs]


def build_train_step(spec: ModelSpec, use_ref: bool = False) -> Callable:
    """(params…, vel…, x, y, sw, lr, mu) -> (params'…, vel'…, loss, correct, conf).

    sw are per-sample gradient weights (1.0 for the baseline); the weighted
    objective is (1/B) * sum_i sw_i * loss_i, which implements importance
    re-weighting (ISWR), Selective-Backprop subset masks, and GradMatch
    subset weights with one artifact.
    """
    n = len(spec.param_specs)

    def step(*args):
        p_leaves = args[:n]
        v_leaves = args[n:2 * n]
        x, y, sw, lr, mu = args[2 * n:]
        params = params_dict(spec, p_leaves)
        vel = params_dict(spec, v_leaves)

        def objective(params):
            logits, _ = forward(spec, params, x, use_ref)
            loss, correct, conf = sample_stats(spec, logits, y, use_ref)
            wmean = jnp.sum(loss * sw) / spec.batch
            return wmean, (loss, correct, conf)

        grads, (loss, correct, conf) = jax.grad(objective, has_aux=True)(params)
        if use_ref:
            new_p, new_v = {}, {}
            for k in params:
                new_p[k], new_v[k] = kref.sgd_momentum(params[k], vel[k], grads[k], lr, mu)
        else:
            new_p, new_v = sgd_momentum_tree(params, vel, grads, lr, mu)
        return (*params_leaves(spec, new_p), *params_leaves(spec, new_v),
                loss, correct, conf)

    return step


def build_fwd_stats(spec: ModelSpec, use_ref: bool = False) -> Callable:
    """(params…, x, y) -> (loss[B], correct[B], conf[B]) — no grad, no update.

    Used by the coordinator for (a) refreshing the hidden list at epoch end
    (paper §3.4, step D.1), (b) the validation pass, and (c) Selective-
    Backprop's selection forward pass.
    """
    n = len(spec.param_specs)

    def fwd(*args):
        params = params_dict(spec, args[:n])
        x, y = args[n:]
        logits, _ = forward(spec, params, x, use_ref)
        return sample_stats(spec, logits, y, use_ref)

    return fwd


def build_fwd_embed(spec: ModelSpec, use_ref: bool = False) -> Callable:
    """(params…, x, y) -> (loss, correct, conf, emb[B,D], probs[B,C]).

    GradMatch's last-layer gradient approximation needs the penultimate
    features and the softmax probabilities: per-sample last-layer gradient
    = (probs - onehot(y)) ⊗ emb (computed on the Rust side).
    """
    assert spec.embed_dim > 0, f"{spec.name} has no embedding output"
    n = len(spec.param_specs)

    def fwd(*args):
        params = params_dict(spec, args[:n])
        x, y = args[n:]
        logits, emb = forward(spec, params, x, use_ref)
        loss, correct, conf = sample_stats(spec, logits, y, use_ref)
        probs = jax.nn.softmax(logits, axis=-1)
        return loss, correct, conf, emb, probs

    return fwd


def example_args(spec: ModelSpec, kind: str):
    """ShapeDtypeStructs matching the artifact calling convention."""
    f32, i32 = jnp.float32, jnp.int32
    p = [jax.ShapeDtypeStruct(ps.shape, f32) for ps in spec.param_specs]
    x = jax.ShapeDtypeStruct((spec.batch, *spec.input_shape), f32)
    y = jax.ShapeDtypeStruct((spec.batch, *spec.label_shape), i32)
    if kind == "train_step":
        sw = jax.ShapeDtypeStruct((spec.batch,), f32)
        lr = jax.ShapeDtypeStruct((), f32)
        mu = jax.ShapeDtypeStruct((), f32)
        return [*p, *p, x, y, sw, lr, mu]
    if kind in ("fwd_stats", "fwd_embed"):
        return [*p, x, y]
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Variant registry — every artifact the Rust side can ask for.
# ---------------------------------------------------------------------------

# Stand-ins (see DESIGN.md §3):
#   mlp_c100_b64  — WRN-28-10 / CIFAR-100
#   cnn_c32_b64   — ResNet-50 / ImageNet-1K proxy
#   cnnw_c32_b64  — EfficientNet-b3 (wider CNN)
#   segnet_b32    — DeepCAM
#   mlp_c64_b64   — DeiT-Tiny / Fractal-3K upstream
#   mlp_c10_b64   — downstream CIFAR-10 transfer head
#   cnn_c32_b{128,256} — Table 11 global-batch scaling
VARIANTS = {
    s.name: s
    for s in [
        mlp_spec("mlp_c100_b64", 64, 64, 128, 100),
        mlp_spec("mlp_c64_b64", 64, 64, 128, 64),
        mlp_spec("mlp_c10_b64", 64, 64, 128, 10),
        cnn_spec("cnn_c32_b64", 64, 8, 3, 16, 32, 64, 32),
        cnn_spec("cnn_c32_b128", 128, 8, 3, 16, 32, 64, 32),
        cnn_spec("cnn_c32_b256", 256, 8, 3, 16, 32, 64, 32),
        cnn_spec("cnnw_c32_b64", 64, 8, 3, 24, 48, 96, 32),
        cnn_spec("cnn_c100_b64", 64, 8, 3, 16, 32, 64, 100),
        segnet_spec("segnet_b32", 32, 16, 3, 16, 2),
    ]
}
