"""AOT lowering: every model variant -> artifacts/<name>_<kind>.hlo.txt.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax>=0.5
emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Also writes artifacts/manifest.json describing the calling convention of
each artifact (parameter names/shapes/init, batch, outputs) for the Rust
runtime (rust/src/runtime/artifact.rs).

Python runs ONCE at build time (`make artifacts`); it is never on the
training request path.  Re-lowering is skipped when the source fingerprint
recorded in the manifest matches (so `make artifacts` is a cheap no-op).

Usage: cd python && python -m compile.aot [--out-dir ../artifacts] [--force]
                                          [--only cnn_c32_b64,...]
"""

from __future__ import annotations

import argparse
import hashlib
import inspect
import json
import os
import sys
import time

import jax
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import fused_loss_stats as k_fls
from .kernels import matmul_bias_act as k_mba
from .kernels import sgd_momentum as k_sgd


def source_fingerprint() -> str:
    """Hash of every module whose change must invalidate the artifacts."""
    h = hashlib.sha256()
    for mod in (M, k_fls, k_mba, k_sgd):
        h.update(inspect.getsource(mod).encode())
    return h.hexdigest()[:16]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


KINDS = ("train_step", "fwd_stats", "fwd_embed")

BUILDERS = {
    "train_step": M.build_train_step,
    "fwd_stats": M.build_fwd_stats,
    "fwd_embed": M.build_fwd_embed,
}


def artifact_kinds(spec: M.ModelSpec):
    for kind in KINDS:
        if kind == "fwd_embed" and spec.embed_dim == 0:
            continue
        yield kind


def lower_variant(spec: M.ModelSpec, kind: str, out_dir: str) -> str:
    fn = BUILDERS[kind](spec)
    args = M.example_args(spec, kind)
    t0 = time.time()
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    fname = f"{spec.name}_{kind}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    print(f"  {fname}: {len(text)} chars in {time.time() - t0:.1f}s", flush=True)
    return fname


def variant_manifest(spec: M.ModelSpec) -> dict:
    return {
        "family": spec.family,
        "batch": spec.batch,
        "input_shape": list(spec.input_shape),
        "label_shape": list(spec.label_shape),
        "classes": spec.classes,
        "embed_dim": spec.embed_dim,
        "param_count": spec.param_count,
        "params": [
            {"name": p.name, "shape": list(p.shape), "init_std": p.init_std}
            for p in spec.param_specs
        ],
        "artifacts": {
            kind: f"{spec.name}_{kind}.hlo.txt" for kind in artifact_kinds(spec)
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--only", default="", help="comma-separated variant names")
    args = ap.parse_args()

    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)
    manifest_path = os.path.join(out_dir, "manifest.json")

    fp = source_fingerprint()
    prev = {}
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            prev = json.load(f)

    only = set(args.only.split(",")) - {""}
    variants = {
        name: spec for name, spec in M.VARIANTS.items() if not only or name in only
    }
    up_to_date = prev.get("fingerprint") == fp and not args.force

    manifest = {
        "fingerprint": fp,
        "convention": {
            "train_step": "(params.., vel.., x, y, sw, lr, mu) -> (params'.., vel'.., loss, correct, conf)",
            "fwd_stats": "(params.., x, y) -> (loss, correct, conf)",
            "fwd_embed": "(params.., x, y) -> (loss, correct, conf, emb, probs)",
        },
        "models": dict(prev.get("models", {})),
    }

    for name, spec in variants.items():
        vm = variant_manifest(spec)
        have_all = all(
            os.path.exists(os.path.join(out_dir, f)) for f in vm["artifacts"].values()
        )
        if up_to_date and have_all and prev.get("models", {}).get(name) == vm:
            print(f"{name}: up to date")
            manifest["models"][name] = vm
            continue
        print(f"{name}: lowering ({spec.param_count} params)")
        for kind in artifact_kinds(spec):
            lower_variant(spec, kind, out_dir)
        manifest["models"][name] = vm

    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote {manifest_path} ({len(manifest['models'])} variants)")


if __name__ == "__main__":
    main()
